//! Optimized-kernel / planner / arena-executor bench — CI's bench-smoke
//! entry point (`cargo bench --bench kernels -- --test` for smoke mode).
//!
//! Beyond printing numbers, this binary *gates* the fast path in release
//! builds: the im2col+GEMM conv must beat the naive reference loop on the
//! 64×64 acceptance shape, and the steady-state arena run must perform
//! zero heap allocations (counted by the installed allocator).

use sol::exec::kernelbench::{conv_speedup, run_kernel_bench, write_bench_json};

#[global_allocator]
static ALLOC: sol::util::alloc::CountingAllocator = sol::util::alloc::CountingAllocator;

fn main() {
    let smoke = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let rows = run_kernel_bench(smoke);
    for r in &rows {
        println!(
            "{:<34} {:>12.0} ns/iter  {:>10} B  {:>3} allocs/run",
            r.op, r.ns_per_iter, r.bytes, r.allocs_per_run
        );
    }
    let speedup = conv_speedup(&rows);
    println!("conv2d 64x64 speedup (naive -> fast.t1): {speedup:.2}x");

    // perf gates (release builds drive this binary)
    let floor = if smoke { 2.0 } else { 5.0 };
    assert!(
        speedup >= floor,
        "optimized conv2d regressed: {speedup:.2}x < {floor}x over naive"
    );
    let steady = rows
        .iter()
        .find(|r| r.op == "arena_exec.fig3_cnn.steady")
        .expect("arena row");
    assert_eq!(
        steady.allocs_per_run, 0,
        "steady-state arena run must not allocate"
    );

    if let Some(pos) = std::env::args().position(|a| a == "--out") {
        if let Some(path) = std::env::args().nth(pos + 1) {
            write_bench_json(std::path::Path::new(&path), &rows, smoke).expect("write json");
            println!("wrote {path}");
        }
    }
}
