//! §Perf microbenchmarks — the measurements behind EXPERIMENTS.md §Perf.
//!
//! Default: L3 hot paths (queue, compiler, Fig-3 harness).  With `--pjrt`
//! also re-measures the L1/L2 artifact timings (slow: ~2 min).

use sol::devsim::DeviceId;
use sol::metrics::Timer;
use sol::passes::{optimize, OptimizeOptions};
use sol::runtime::queue::AsyncQueue;
use sol::workloads::NetId;

fn l3() {
    let n = 100_000;
    let q = AsyncQueue::new(1 << 30);
    let t = Timer::start();
    for _ in 0..n {
        q.submit(|| {});
    }
    q.sync().unwrap();
    println!("queue submit+drain: {:>7.0} ns/op", t.ms() * 1e6 / n as f64);

    let q = AsyncQueue::new(1 << 30);
    let t = Timer::start();
    for _ in 0..n {
        let p = q.malloc_async(4096);
        q.free_async(p);
    }
    q.sync().unwrap();
    println!("virtual malloc/free: {:>6.0} ns/pair", t.ms() * 1e6 / n as f64);

    let g = NetId::Densenet169.build(1);
    let t = Timer::start();
    for _ in 0..10 {
        std::hint::black_box(optimize(&g, &OptimizeOptions::new(DeviceId::AuroraVE10B)));
    }
    println!("optimize(densenet169, 595 layers): {:.1} ms", t.ms() / 10.0);

    let t = Timer::start();
    let rows = sol::exec::fig3::fig3_grid(false, &Default::default());
    println!("fig3 full grid ({} rows): {:.1} ms", rows.len(), t.ms());
}

fn l12_pjrt() {
    use sol::runtime::pjrt::{HostTensor, PjrtEngine};
    use sol::util::XorShift;
    let Ok(e) = PjrtEngine::new() else {
        println!("(artifacts not built; skipping PJRT timings)");
        return;
    };
    let mut rng = XorShift::new(1);
    let time_entry = |entry: &str, inputs: &[HostTensor], reps: usize| -> f64 {
        e.run(entry, inputs).unwrap();
        let t = Timer::start();
        for _ in 0..reps {
            e.run(entry, inputs).unwrap();
        }
        t.ms() / reps as f64
    };
    let sig = e.manifest.entry("mlp_train_sol_b16").unwrap().clone();
    let mut inputs: Vec<HostTensor> = sig.inputs[..6]
        .iter()
        .map(|s| HostTensor::F32(rng.normal_vec(s.elems(), 0.01)))
        .collect();
    inputs.push(HostTensor::F32(rng.normal_vec(16 * 8192, 0.1)));
    inputs.push(HostTensor::I32((0..16).map(|i| i % 10).collect()));
    println!("mlp_train_sol_b16: {:.0} ms", time_entry("mlp_train_sol_b16", &inputs, 2));
    println!("mlp_train_ref_b16: {:.0} ms", time_entry("mlp_train_ref_b16", &inputs, 2));
    let ci = vec![
        HostTensor::F32(rng.normal_vec(16 * 58 * 58 * 64, 0.1)),
        HostTensor::F32(rng.normal_vec(3 * 3 * 64 * 64, 0.1)),
        HostTensor::F32(rng.normal_vec(64, 0.1)),
    ];
    println!("conv_site_sol_b16: {:.1} ms", time_entry("conv_site_sol_b16", &ci, 3));
    println!("conv_site_ref_b16: {:.1} ms", time_entry("conv_site_ref_b16", &ci, 3));
}

fn main() {
    l3();
    if std::env::args().any(|a| a == "--pjrt") {
        l12_pjrt();
    }
}
