//! E2 — Table I: the hardware devices used in the evaluation, printed
//! from the exact machine-readable specs the simulator runs on.

use sol::devsim::DeviceId;
use sol::metrics::format_table;

fn main() {
    let rows: Vec<Vec<String>> = DeviceId::ALL
        .iter()
        .map(|d| {
            let s = d.spec();
            vec![
                s.vendor.to_string(),
                s.model.to_string(),
                match s.kind {
                    sol::devsim::DeviceKind::Cpu => "CPU",
                    sol::devsim::DeviceKind::Gpu => "GPU",
                    sol::devsim::DeviceKind::Vpu => "VPU",
                }
                .to_string(),
                format!("{:.2}", s.tflops),
                format!("{:.2}", s.bandwidth_gbs),
            ]
        })
        .collect();
    println!("Table I: Hardware devices used in our evaluation");
    println!(
        "{}",
        format_table(&["Vendor", "Model", "Type", "TFLOP/s", "Bandwidth(GB/s)"], &rows)
    );
    println!("(paper values: 0.88/119.21, 4.30/1200.00, 5.30/243.30, 14.90/651.30)");
}
