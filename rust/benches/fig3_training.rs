//! E4 — Fig. 3 (right): training step time, B=16 (CNN) / B=64 (MLP),
//! 13 networks × 4 devices × {baseline, SOL native, SOL transparent}.

use sol::devsim::DeviceId;
use sol::exec::fig3::{fig3_grid, headline_speedups};
use sol::metrics::{format_table, Timer};
use sol::workloads::NetId;

fn main() {
    let t = Timer::start();
    let rows = fig3_grid(true, &Default::default());
    let mut table = Vec::new();
    for net in NetId::ALL {
        let mut row = vec![net.name().to_string()];
        for dev in DeviceId::ALL {
            let r = rows.iter().find(|r| r.net == net && r.device == dev).unwrap();
            row.push(r.baseline_ms.map_or("n/a".into(), |b| format!("{b:.2}")));
            row.push(format!("{:.2}", r.sol_ms));
            row.push(format!("{:.2}", r.sol_to_ms));
        }
        table.push(row);
    }
    println!("Fig. 3 (right) — training, B=16 CNN / B=64 MLP, step time in ms");
    println!(
        "{}",
        format_table(
            &[
                "net", "cpu:pt", "cpu:sol", "cpu:TO", "ve:tfve", "ve:sol", "ve:TO",
                "p4k:pt", "p4k:sol", "p4k:TO", "titan:pt", "titan:sol", "titan:TO",
            ],
            &table
        )
    );
    println!("E5 headline max speedups (paper: CPU 2.41x, Aurora 4.18x, GPU 1.22x):");
    for (d, s) in headline_speedups(&rows) {
        println!("  {:?}: {s:.2}x", d);
    }
    // §VI-D: native vs TO gap at training on offload devices
    println!("\nnative-vs-TO training advantage (ms saved per step, §V-A):");
    for net in [NetId::Resnet50, NetId::Vgg16, NetId::Mlp] {
        let r = rows
            .iter()
            .find(|r| r.net == net && r.device == DeviceId::AuroraVE10B)
            .unwrap();
        println!("  {:<10} TO {:.2} -> native {:.2}", net.name(), r.sol_to_ms, r.sol_ms);
    }
    println!("\n[fig3_training completed in {:.1} s]", t.ms() / 1e3);
}
