//! E8b — compile-cache ablation: compile the same workload N times with
//! the content-addressed cache on (one `Session`) vs off (a fresh
//! pipeline per call) and report amortized compile time.
//!
//! Extends the E8 compile-time story (paper §III-A: "usually less than
//! 1 min including the auto-tuning"): under repeated traffic — the same
//! model (re)deployed across workers, devices, or restarts — SOL pays
//! the pipeline once per `(graph, device, config)` and serves the rest
//! from the cache.
//!
//! Run: `cargo bench --bench cache_ablation [-- N]`

use sol::devsim::DeviceId;
use sol::metrics::{format_table, Timer};
use sol::session::Session;
use sol::workloads::NetId;

fn main() {
    let n: usize = std::env::args()
        .skip(1)
        .find_map(|a| a.parse().ok())
        .unwrap_or(32);
    let nets = [NetId::Resnet18, NetId::Resnet50, NetId::Vgg16, NetId::Mnasnet1_0];
    let dev = DeviceId::AuroraVE10B;

    println!("compile-cache ablation: {n} compiles per net on {dev:?}\n");
    let mut rows = Vec::new();
    for net in nets {
        let g = net.build(1);

        // --- cache off: every call runs the full pipeline ---
        let t = Timer::start();
        for _ in 0..n {
            let session = Session::new(); // fresh cache each time
            let _ = session.compile(&g, dev);
        }
        let off_ms = t.ms() / n as f64;

        // --- cache on: one session, N compiles, N-1 hits ---
        let session = Session::new();
        let t = Timer::start();
        for _ in 0..n {
            let _ = session.compile(&g, dev);
        }
        let on_ms = t.ms() / n as f64;
        assert_eq!(session.cache().misses(), 1, "{}: expected one miss", net.name());
        assert_eq!(session.cache().hits(), (n - 1) as u64);

        rows.push(vec![
            net.name().to_string(),
            format!("{off_ms:.3}"),
            format!("{on_ms:.4}"),
            format!("{:.0}x", off_ms / on_ms.max(1e-6)),
            format!("{}/{}", session.cache().hits(), session.cache().misses()),
        ]);
    }
    println!(
        "{}",
        format_table(
            &["net", "cache-off ms/compile", "cache-on ms/compile", "amortized speedup", "hit/miss"],
            &rows
        )
    );
}
