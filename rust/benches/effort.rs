//! E1 — the §VI-A programming-effort table, measured on THIS repository.
//!
//! Paper: X86 ~3,000 LoC, ARM64 +300, NVIDIA ~2,400, SX-Aurora ~2,200
//! (+800 native-tensor kernels), PyTorch frontend ~1,200 (+1,200 native
//! integration) — versus 26,000 (CPU) and 47,000 (CUDA) lines *inside*
//! PyTorch.  The claim is the *ratio*: a backend costs O(thousands),
//! in-tree support costs O(tens of thousands).  Here we print the same
//! table over our components and the equivalent ratio.

use std::path::Path;

use sol::backends::default_registry;
use sol::metrics::format_table;

fn loc(rel: &str) -> usize {
    fn walk(p: &Path) -> usize {
        let mut n = 0;
        if p.is_file() {
            if p.extension().is_some_and(|x| x == "rs" || x == "py") {
                n += std::fs::read_to_string(p).map_or(0, |s| {
                    s.lines().filter(|l| !l.trim().is_empty()).count()
                });
            }
            return n;
        }
        if let Ok(rd) = std::fs::read_dir(p) {
            for e in rd.flatten() {
                n += walk(&e.path());
            }
        }
        n
    }
    walk(&Path::new(env!("CARGO_MANIFEST_DIR")).join(rel))
}

/// Source file of one registered backend — the registry (not a hardcoded
/// list) names what exists; only the name→file mapping lives here.
fn backend_file(name: &str) -> &'static str {
    match name {
        "x86" => "rust/src/backends/x86.rs",
        "arm64" => "rust/src/backends/arm64.rs",
        "nvidia" => "rust/src/backends/nvidia.rs",
        "sx-aurora" => "rust/src/backends/aurora.rs",
        other => panic!("no source mapping for backend '{other}' — extend backend_file()"),
    }
}

fn main() {
    // enumerate the shipped backends through the registry so a newly
    // registered device shows up here (or fails loudly) instead of being
    // silently missing from the effort table
    let registry = default_registry();
    let backend_loc = |name: &str| -> usize {
        registry.by_name(name).expect("registered backend");
        loc(backend_file(name))
    };
    for b in registry.iter() {
        let _ = backend_file(b.name()); // every backend must be mapped
    }
    let x86 = backend_loc("x86");
    let arm = backend_loc("arm64");
    let nv = backend_loc("nvidia");
    let ve = backend_loc("sx-aurora");
    let native = loc("rust/src/frontend/native.rs");
    let frontend = loc("rust/src/frontend/extract.rs")
        + loc("rust/src/frontend/inject.rs")
        + loc("rust/src/frontend/offload.rs");
    let shared_dfp = loc("rust/src/dfp");
    let shared_dnn = loc("rust/src/dnn");
    let framework = loc("rust/src/framework");
    let kernels = loc("python/compile/kernels");

    let rows = vec![
        vec!["X86 backend".into(), x86.to_string(), "~3,000".into()],
        vec!["ARM64 backend (inherits X86)".into(), arm.to_string(), "+300".into()],
        vec!["NVIDIA backend".into(), nv.to_string(), "~2,400".into()],
        vec!["SX-Aurora backend".into(), ve.to_string(), "~2,200".into()],
        vec!["  + native tensor kernels".into(), native.to_string(), "+800".into()],
        vec!["frontend (extract/inject/TO)".into(), frontend.to_string(), "~1,200".into()],
        vec!["shared DFP module".into(), shared_dfp.to_string(), "(shared)".into()],
        vec!["shared DNN module".into(), shared_dnn.to_string(), "(shared)".into()],
        vec!["L1 pallas kernels".into(), kernels.to_string(), "(shared)".into()],
        vec!["-- framework itself --".into(), framework.to_string(), "26k-47k/device".into()],
    ];
    println!("E1: programming effort (non-empty LoC), this repo vs paper §VI-A");
    println!("{}", format_table(&["component", "LoC (ours)", "paper"], &rows));

    // The paper's headline ratio: in-framework device support costs 10-20x
    // a SOL-style backend.  Ours: framework vs (backend + share of DFP).
    let backend_cost = ve + native;
    let ratio = framework as f64 / backend_cost as f64;
    println!(
        "framework:backend ratio = {framework}:{backend_cost} = {ratio:.1}x (paper: 26000:3000 = 8.7x .. 47000:2400 = 19.6x)"
    );
    assert!(ratio > 2.0, "backends must stay an order cheaper than the framework");
    // ARM64 "inherits most functionality" claim: far smaller than X86+shared
    assert!(arm < (x86 + shared_dfp) / 4);
    println!("effort OK");
}
