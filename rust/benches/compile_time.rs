//! E8 — §III-A compile-time claim: "This entire optimization procedure
//! requires usually less than 1 min (including the auto-tuning)".
//!
//! Measures real `optimize()` wall time per network (IR passes, module
//! assignment, fusion, codegen, layout) plus the simulated auto-tuning
//! workload cost, and asserts the <1 min budget.

use sol::devsim::DeviceId;
use sol::metrics::{format_table, Timer};
use sol::passes::{optimize, OptimizeOptions};
use sol::util::BenchStats;
use sol::workloads::NetId;

fn main() {
    let mut rows = Vec::new();
    let t_all = Timer::start();
    for net in NetId::ALL {
        let g = net.build(1);
        let mut autotune_us = 0.0;
        let mut kernels = 0;
        let stats = BenchStats::measure(net.name(), 1, 5, || {
            let m = optimize(&g, &OptimizeOptions::new(DeviceId::AuroraVE10B));
            autotune_us = m.autotune_us;
            kernels = m.kernel_count();
        });
        let total_ms = stats.median() + autotune_us / 1e3;
        assert!(
            total_ms < 60_000.0,
            "{}: compile {total_ms:.0} ms exceeds the paper's 1-minute budget",
            net.name()
        );
        rows.push(vec![
            net.name().to_string(),
            g.layer_count().to_string(),
            kernels.to_string(),
            format!("{:.1}", stats.median()),
            format!("{:.1}", autotune_us / 1e3),
            format!("{:.1}", total_ms),
        ]);
    }
    println!("E8: sol.optimize() cost per network (paper claim: < 1 min incl. auto-tuning)");
    println!(
        "{}",
        format_table(
            &["net", "layers", "kernels", "compile ms", "autotune ms", "total ms"],
            &rows
        )
    );
    println!("[compile_time completed in {:.1} s]", t_all.ms() / 1e3);
}
