//! E7 — §IV-C ablation: gathered/packed memcopies (VEO-udma path) vs
//! per-tensor latency-optimized copies (plain VEoffload), over the real
//! parameter sets of the evaluation networks.

use sol::devsim::{DeviceId, EfficiencyTable, SimEngine, SimStep};
use sol::ir::Op;
use sol::metrics::format_table;
use sol::runtime::memcpy::{plan_transfers, Transfer, TransferPlan};
use sol::workloads::NetId;

fn main() {
    let eff = EfficiencyTable::default();
    let spec = DeviceId::AuroraVE10B.spec();
    let eng = SimEngine::new(spec, eff, false);
    let mut rows = Vec::new();
    for net in NetId::ALL {
        let g = net.build(1);
        // one Transfer per parameter tensor, like a model upload (§V-A)
        let reqs: Vec<Transfer> = g
            .nodes
            .iter()
            .filter_map(|n| {
                let inp = n.inputs.first().map(|&i| &g.node(i).meta)?;
                let b = n.op.param_count(inp) * 4;
                (b > 0 && !matches!(n.op, Op::Input)).then_some(Transfer {
                    bytes: b,
                    to_device: true,
                })
            })
            .collect();

        // unpacked: every tensor pays link latency
        let unpacked: Vec<SimStep> =
            reqs.iter().map(|t| SimStep::H2D { bytes: t.bytes, packed: false }).collect();
        // packed: the planner gathers adjacent small tensors
        let plans = plan_transfers(&reqs);
        let packed: Vec<SimStep> = plans
            .iter()
            .map(|p| match p {
                TransferPlan::Single(t) => SimStep::H2D { bytes: t.bytes, packed: false },
                TransferPlan::Packed { total_bytes, .. } => {
                    SimStep::H2D { bytes: *total_bytes, packed: true }
                }
            })
            .collect();

        let tu = eng.run(&unpacked).total_ms();
        let tp = eng.run(&packed).total_ms();
        rows.push(vec![
            net.name().to_string(),
            reqs.len().to_string(),
            plans.len().to_string(),
            format!("{tu:.3}"),
            format!("{tp:.3}"),
            format!("{:.2}x", tu / tp),
        ]);
    }
    println!("E7: parameter upload to SX-Aurora — per-tensor vs packed (VEO-udma)");
    println!(
        "{}",
        format_table(
            &["net", "tensors", "wire ops", "unpacked ms", "packed ms", "speedup"],
            &rows
        )
    );
    println!("(packing wins most on many-small-tensor nets: shufflenet/mnasnet/densenet)");
}
