//! E3 — Fig. 3 (left): inference latency, B=1, 13 networks × 4 devices ×
//! {baseline, SOL, SOL(TO)}.  Regenerates the paper's figure as a table
//! plus the §I headline per-device max speedups (E5).
//!
//! Pass `--calibrate` to anchor the CPU efficiency table on real PJRT
//! measurements first (adds ~a minute).

use sol::devsim::DeviceId;
use sol::exec::calibrate;
use sol::exec::fig3::{fig3_grid, headline_speedups};
use sol::metrics::{format_table, Timer};
use sol::workloads::NetId;

fn main() {
    let calibrate_flag = std::env::args().any(|a| a == "--calibrate");
    let (eff, cal) = if calibrate_flag {
        calibrate::calibrate_or_default()
    } else {
        (Default::default(), None)
    };
    if let Some(c) = &cal {
        println!(
            "[calibration] gemm {:.1} GF/s | fused conv {:.1} GF/s | measured fusion speedup {:.2}x | est host peak {:.1} GF/s",
            c.matmul_gflops, c.fused_conv_gflops, c.fusion_speedup, c.est_host_peak_gflops
        );
    }

    let t = Timer::start();
    let rows = fig3_grid(false, &eff);
    let mut table = Vec::new();
    for net in NetId::ALL {
        let mut row = vec![net.name().to_string()];
        for dev in DeviceId::ALL {
            let r = rows.iter().find(|r| r.net == net && r.device == dev).unwrap();
            row.push(r.baseline_ms.map_or("n/a".into(), |b| format!("{b:.2}")));
            row.push(format!("{:.2}", r.sol_ms));
            row.push(format!("{:.2}", r.sol_to_ms));
        }
        table.push(row);
    }
    println!("\nFig. 3 (left) — inference, B=1, execution time in ms");
    println!(
        "{}",
        format_table(
            &[
                "net", "cpu:pt", "cpu:sol", "cpu:TO", "ve:tfve", "ve:sol", "ve:TO",
                "p4k:pt", "p4k:sol", "p4k:TO", "titan:pt", "titan:sol", "titan:TO",
            ],
            &table
        )
    );
    println!("E5 headline max speedups (paper: CPU 7.79x, Aurora 25.41x, GPU 4.37x):");
    for (d, s) in headline_speedups(&rows) {
        println!("  {:?}: {s:.2}x", d);
    }
    println!("\n[fig3_inference completed in {:.1} s]", t.ms() / 1e3);
}
