//! Property-based tests over coordinator invariants (in-tree harness: the
//! offline build has no proptest crate; `sol::util::XorShift` drives the
//! generation, failures print the seed for reproduction).

use sol::devsim::{DeviceId, DeviceMemory, EfficiencyTable};
use sol::framework::{install_default, Module, Tensor};
use sol::frontend::SolModel;
use sol::ir::Graph;
use sol::passes::{elide_relu_maxpool, optimize, OptimizeOptions};
use sol::runtime::memcpy::{plan_transfers, Transfer, TransferPlan};
use sol::runtime::queue::{AsyncQueue, VirtualPtr};
use sol::session::CacheKey;
use sol::util::{Json, XorShift};

const CASES: usize = 40;

/// Random small CNN as both a framework module and its input shape.
fn random_module(rng: &mut XorShift) -> (Module, Vec<usize>) {
    let c0 = *rng.pick(&[1usize, 2, 3]);
    let hw = *rng.pick(&[8usize, 12, 16]);
    let mut layers = Vec::new();
    let mut c = c0;
    let mut size = hw;
    let depth = rng.range(1, 4);
    for li in 0..depth {
        let cout = *rng.pick(&[4usize, 6, 8]);
        layers.push(Module::conv2d(c, cout, 3, 1, 1, 100 + li as u64));
        c = cout;
        match rng.below(3) {
            0 => layers.push(Module::ReLU),
            1 => {
                layers.push(Module::batch_norm(c));
                layers.push(Module::ReLU);
            }
            _ => {}
        }
        if size >= 8 && rng.below(2) == 0 {
            layers.push(Module::MaxPool2d { k: 2, stride: 2, pad: 0 });
            size /= 2;
        }
    }
    layers.push(Module::Flatten);
    layers.push(Module::linear(c * size * size, 5, 7));
    (Module::Sequential(layers), vec![1, c0, hw, hw])
}

/// PROPERTY: for any architecture, SolModel::forward == framework forward.
#[test]
fn prop_sol_model_equals_framework() {
    let reg = install_default();
    for seed in 0..CASES as u64 {
        let mut rng = XorShift::new(seed);
        let (m, shape) = random_module(&mut rng);
        let x = Tensor::randn(&shape, seed + 999, 0.5);
        let want = m.forward(&reg, &x).unwrap().to_f32().unwrap();
        for dev in [DeviceId::Xeon6126, DeviceId::AuroraVE10B] {
            let sol =
                SolModel::optimize(&m, &shape, "prop", &OptimizeOptions::new(dev)).unwrap();
            let got = sol.forward(&x).unwrap().to_f32().unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert!((a - b).abs() < 1e-4, "seed {seed} dev {dev:?}: {a} vs {b}");
            }
        }
    }
}

/// PROPERTY: elision never changes parameter count, conv FLOPs, or output
/// shape, and never *adds* layers.
#[test]
fn prop_elision_invariants() {
    for seed in 0..CASES as u64 {
        let mut rng = XorShift::new(seed + 500);
        let g = random_graph(&mut rng);
        let (e, removed) = elide_relu_maxpool(&g);
        assert_eq!(g.param_count(), e.param_count(), "seed {seed}");
        assert_eq!(e.nodes.len() + removed, g.nodes.len(), "seed {seed}");
        assert_eq!(
            g.node(g.output()).meta.shape(),
            e.node(e.output()).meta.shape(),
            "seed {seed}"
        );
    }
}

fn random_graph(rng: &mut XorShift) -> Graph {
    let mut g = Graph::new("prop");
    let mut x = g.input_image(*rng.pick(&[1usize, 2]), *rng.pick(&[3usize, 8]), 16, 16);
    for _ in 0..rng.range(2, 8) {
        x = match rng.below(6) {
            0 => g.conv(x, *rng.pick(&[4usize, 8, 16]), 3, 1, 1, 1),
            1 => g.relu(x),
            2 => g.batch_norm(x),
            3 if g.node(x).meta.spatial().0 >= 4 => g.max_pool(x, 2, 2, 0),
            4 => g.dropout(x),
            _ => g.relu(x),
        };
    }
    g
}

/// PROPERTY: cache keys are name-blind but structure-sighted — a
/// rename-only mutation of any graph lands on the same content address
/// (hit), a structural mutation always moves it (miss), and both
/// independent digests move together.
#[test]
fn prop_cache_key_hits_renames_misses_structure() {
    const FP: u64 = 0x50f7_ba11;
    for seed in 0..CASES as u64 {
        let mut rng = XorShift::new(seed + 4000);
        let g = random_graph(&mut rng);
        let key = CacheKey::of(&g, DeviceId::Xeon6126, FP);
        assert_ne!(key.graph, key.graph2, "seed {seed}: digests must be independent");

        // rename-only mutation: same content address, bit for bit
        let mut renamed = g.clone();
        renamed.name = format!("renamed-{seed}");
        for n in &mut renamed.nodes {
            n.name = format!("layer_{}_{seed}", n.id);
        }
        assert_eq!(key, CacheKey::of(&renamed, DeviceId::Xeon6126, FP), "seed {seed}");

        // structural mutations: appending work, or rebuilding at another
        // batch size, must move BOTH digests (a miss under either hash)
        let mut grown = g.clone();
        grown.relu(grown.output());
        let grown_key = CacheKey::of(&grown, DeviceId::Xeon6126, FP);
        assert_ne!(key, grown_key, "seed {seed}: structural change must miss");
        assert_ne!(key.graph, grown_key.graph, "seed {seed}: FNV digest static");
        assert_ne!(key.graph2, grown_key.graph2, "seed {seed}: second digest static");

        // other key ingredients separate too
        assert_ne!(key, CacheKey::of(&g, DeviceId::TitanV, FP), "seed {seed}");
        assert_ne!(key, CacheKey::of(&g, DeviceId::Xeon6126, FP + 1), "seed {seed}");
    }
}

/// PROPERTY: a forced 64-bit FNV collision (adversarially equal primary
/// digest AND node count) is still caught by the second independent hash
/// — structurally different graphs never share a full `CacheKey`.
#[test]
fn prop_second_hash_catches_forced_fnv_collisions() {
    const FP: u64 = 0xc011_1de5;
    let mut checked = 0;
    for seed in 0..CASES as u64 {
        let mut rng = XorShift::new(seed + 4400);
        let g1 = random_graph(&mut rng);
        let g2 = random_graph(&mut rng);
        let k1 = CacheKey::of(&g1, DeviceId::Xeon6126, FP);
        let mut k2 = CacheKey::of(&g2, DeviceId::Xeon6126, FP);
        if k1.graph == k2.graph {
            continue; // same structure drawn twice: nothing to force
        }
        // adversary forces the FNV half and defeats the node-count
        // tripwire; only graph2 is left to tell the graphs apart
        k2.graph = k1.graph;
        k2.nodes = k1.nodes;
        assert_ne!(k1, k2, "seed {seed}: forced FNV collision aliased the key");
        assert_ne!(k1.graph2, k2.graph2, "seed {seed}: second digest collided too");
        checked += 1;
    }
    assert!(checked >= CASES / 2, "too few distinct pairs exercised ({checked})");
}

/// PROPERTY: the optimizer's schedule covers all compute — effective FLOPs
/// are positive, no kernel exceeds the whole graph's raw FLOPs, and fusing
/// never increases HBM traffic.
#[test]
fn prop_optimizer_schedule_invariants() {
    for seed in 0..CASES as u64 {
        let mut rng = XorShift::new(seed + 900);
        let g = random_graph(&mut rng);
        if g.flops() == 0 {
            continue;
        }
        for dev in [DeviceId::Xeon6126, DeviceId::TitanV] {
            let mut opts = OptimizeOptions::new(dev);
            let fused = optimize(&g, &opts);
            opts.enable_fusion = false;
            let unfused = optimize(&g, &opts);
            assert!(fused.total_flops() > 0, "seed {seed}");
            assert!(
                fused.kernel_count() <= unfused.kernel_count(),
                "seed {seed}: fusion increased kernel count"
            );
            assert!(
                fused.total_hbm_bytes() <= unfused.total_hbm_bytes(),
                "seed {seed}: fusion increased traffic"
            );
        }
    }
}

/// PROPERTY: the transfer planner conserves bytes, preserves direction
/// within every packed segment, and never packs a large tensor.
#[test]
fn prop_memcpy_planner() {
    for seed in 0..200u64 {
        let mut rng = XorShift::new(seed + 1300);
        let reqs: Vec<Transfer> = (0..rng.range(0, 40))
            .map(|_| Transfer {
                bytes: *rng.pick(&[64usize, 4096, 100_000, 1 << 20, 600 << 10]),
                to_device: rng.below(2) == 0,
            })
            .collect();
        let plans = plan_transfers(&reqs);
        let total: usize = plans.iter().map(|p| p.total_bytes()).sum();
        assert_eq!(total, reqs.iter().map(|t| t.bytes).sum::<usize>(), "seed {seed}");
        for p in &plans {
            if let TransferPlan::Packed { transfers, .. } = p {
                assert!(transfers.len() >= 3, "seed {seed}: packed too few");
                let dir = transfers[0].to_device;
                assert!(transfers.iter().all(|t| t.to_device == dir), "seed {seed}");
                assert!(
                    transfers.iter().all(|t| t.bytes < 256 * 1024),
                    "seed {seed}: large tensor packed"
                );
            }
        }
    }
}

/// PROPERTY: DeviceMemory never double-books bytes; used == sum(live);
/// alloc-after-free reuses space (no unbounded growth under churn).
#[test]
fn prop_device_memory_churn() {
    for seed in 0..60u64 {
        let mut rng = XorShift::new(seed + 1700);
        let mut mem = DeviceMemory::new(1 << 22);
        let mut live: Vec<(u64, u64)> = Vec::new();
        let mut expected_used = 0u64;
        for _ in 0..300 {
            if live.is_empty() || rng.below(5) < 3 {
                let size = rng.range(1, 60_000) as u64;
                if let Ok(base) = mem.alloc(size) {
                    let aligned = size.max(1).next_multiple_of(64);
                    live.push((base, aligned));
                    expected_used += aligned;
                }
            } else {
                let idx = rng.below(live.len());
                let (base, size) = live.swap_remove(idx);
                mem.free(base).unwrap();
                expected_used -= size;
            }
            assert_eq!(mem.used, expected_used, "seed {seed}");
        }
        // no overlap among live regions
        let mut regions = live.clone();
        regions.sort();
        for w in regions.windows(2) {
            assert!(w[0].0 + w[0].1 <= w[1].0, "seed {seed}: overlap");
        }
    }
}

/// PROPERTY: async queue executes everything exactly once, in order, for
/// arbitrary interleavings of malloc/free/work/sync.
#[test]
fn prop_queue_linearizes() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    for seed in 0..30u64 {
        let mut rng = XorShift::new(seed + 2100);
        let q = AsyncQueue::new(1 << 24);
        let counter = Arc::new(AtomicUsize::new(0));
        let mut ptrs: Vec<VirtualPtr> = Vec::new();
        let mut submitted = 0usize;
        for _ in 0..rng.range(10, 120) {
            match rng.below(4) {
                0 => ptrs.push(q.malloc_async(rng.range(64, 4096) as u64)),
                1 if !ptrs.is_empty() => {
                    let p = ptrs.swap_remove(rng.below(ptrs.len()));
                    q.free_async(p);
                }
                2 if !ptrs.is_empty() => {
                    let p = *rng.pick(&ptrs);
                    let c = counter.clone();
                    let expect = submitted;
                    submitted += 1;
                    q.submit_with_ptrs(vec![p], move |addrs| {
                        assert!(!addrs.is_empty());
                        let prev = c.fetch_add(1, Ordering::SeqCst);
                        assert_eq!(prev, expect, "out of order");
                    });
                }
                _ => {
                    let c = counter.clone();
                    let expect = submitted;
                    submitted += 1;
                    q.submit(move || {
                        let prev = c.fetch_add(1, Ordering::SeqCst);
                        assert_eq!(prev, expect, "out of order");
                    });
                }
            }
        }
        q.sync().unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), submitted, "seed {seed}");
    }
}

/// PROPERTY: JSON writer/parser round-trips arbitrary values.
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut XorShift, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(100000) as f64) - 5000.0),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| *rng.pick(&['a', 'ü', '"', '\\', '\n', 'z'])).collect())
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..300u64 {
        let mut rng = XorShift::new(seed + 2500);
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(v, back, "seed {seed}");
    }
}

/// PROPERTY: cost model is monotone — more flops or more bytes never makes
/// a kernel faster.
#[test]
fn prop_cost_monotone() {
    let t = EfficiencyTable::default();
    for seed in 0..200u64 {
        let mut rng = XorShift::new(seed + 3000);
        let spec = DeviceId::ALL[rng.below(4)].spec();
        let class = *rng.pick(&[
            sol::devsim::KernelClass::LibraryMatmul,
            sol::devsim::KernelClass::DfpFused,
            sol::devsim::KernelClass::Elementwise,
        ]);
        let f = rng.range(1, 1 << 26);
        let b = rng.range(1, 1 << 24);
        let frac = 0.1 + 0.9 * rng.f32() as f64;
        let base = t.kernel_us(&spec, class, f, b, frac);
        assert!(t.kernel_us(&spec, class, f * 2, b, frac) >= base, "seed {seed}");
        assert!(t.kernel_us(&spec, class, f, b * 2, frac) >= base, "seed {seed}");
    }
}
