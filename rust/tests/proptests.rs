//! Property-based tests over coordinator invariants (in-tree harness: the
//! offline build has no proptest crate; `sol::util::XorShift` drives the
//! generation, failures print the seed for reproduction).

use sol::devsim::{DeviceId, DeviceMemory, EfficiencyTable};
use sol::framework::dispatcher::Attrs;
use sol::framework::ops_fast::register_cpu_fast_kernels;
use sol::framework::{install_default, DeviceType, Tensor};
use sol::frontend::SolModel;
use sol::ir::{Graph, Op};
use sol::passes::{elide_relu_maxpool, optimize, OptimizeOptions};
use sol::runtime::memcpy::{plan_transfers, Transfer, TransferPlan};
use sol::runtime::queue::{AsyncQueue, VirtualPtr};
use sol::session::{plan_memory, CacheKey};
use sol::util::gen::{random_graph, random_module};
use sol::util::{Json, XorShift};

const CASES: usize = 40;

/// PROPERTY: for any architecture, SolModel::forward == framework forward.
#[test]
fn prop_sol_model_equals_framework() {
    let reg = install_default();
    for seed in 0..CASES as u64 {
        let mut rng = XorShift::new(seed);
        let (m, shape) = random_module(&mut rng);
        let x = Tensor::randn(&shape, seed + 999, 0.5);
        let want = m.forward(&reg, &x).unwrap().to_f32().unwrap();
        for dev in [DeviceId::Xeon6126, DeviceId::AuroraVE10B] {
            let sol =
                SolModel::optimize(&m, &shape, "prop", &OptimizeOptions::new(dev)).unwrap();
            let got = sol.forward(&x).unwrap().to_f32().unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert!((a - b).abs() < 1e-4, "seed {seed} dev {dev:?}: {a} vs {b}");
            }
        }
    }
}

/// PROPERTY: elision never changes parameter count, conv FLOPs, or output
/// shape, and never *adds* layers.
#[test]
fn prop_elision_invariants() {
    for seed in 0..CASES as u64 {
        let mut rng = XorShift::new(seed + 500);
        let g = random_graph(&mut rng);
        let (e, removed) = elide_relu_maxpool(&g);
        assert_eq!(g.param_count(), e.param_count(), "seed {seed}");
        assert_eq!(e.nodes.len() + removed, g.nodes.len(), "seed {seed}");
        assert_eq!(
            g.node(g.output()).meta.shape(),
            e.node(e.output()).meta.shape(),
            "seed {seed}"
        );
    }
}

/// PROPERTY: cache keys are name-blind but structure-sighted — a
/// rename-only mutation of any graph lands on the same content address
/// (hit), a structural mutation always moves it (miss), and both
/// independent digests move together.
#[test]
fn prop_cache_key_hits_renames_misses_structure() {
    const FP: u64 = 0x50f7_ba11;
    for seed in 0..CASES as u64 {
        let mut rng = XorShift::new(seed + 4000);
        let g = random_graph(&mut rng);
        let key = CacheKey::of(&g, DeviceId::Xeon6126, FP);
        assert_ne!(key.graph, key.graph2, "seed {seed}: digests must be independent");

        // rename-only mutation: same content address, bit for bit
        let mut renamed = g.clone();
        renamed.name = format!("renamed-{seed}");
        for n in &mut renamed.nodes {
            n.name = format!("layer_{}_{seed}", n.id);
        }
        assert_eq!(key, CacheKey::of(&renamed, DeviceId::Xeon6126, FP), "seed {seed}");

        // structural mutations: appending work, or rebuilding at another
        // batch size, must move BOTH digests (a miss under either hash)
        let mut grown = g.clone();
        grown.relu(grown.output());
        let grown_key = CacheKey::of(&grown, DeviceId::Xeon6126, FP);
        assert_ne!(key, grown_key, "seed {seed}: structural change must miss");
        assert_ne!(key.graph, grown_key.graph, "seed {seed}: FNV digest static");
        assert_ne!(key.graph2, grown_key.graph2, "seed {seed}: second digest static");

        // other key ingredients separate too
        assert_ne!(key, CacheKey::of(&g, DeviceId::TitanV, FP), "seed {seed}");
        assert_ne!(key, CacheKey::of(&g, DeviceId::Xeon6126, FP + 1), "seed {seed}");
    }
}

/// PROPERTY: a forced 64-bit FNV collision (adversarially equal primary
/// digest AND node count) is still caught by the second independent hash
/// — structurally different graphs never share a full `CacheKey`.
#[test]
fn prop_second_hash_catches_forced_fnv_collisions() {
    const FP: u64 = 0xc011_1de5;
    let mut checked = 0;
    for seed in 0..CASES as u64 {
        let mut rng = XorShift::new(seed + 4400);
        let g1 = random_graph(&mut rng);
        let g2 = random_graph(&mut rng);
        let k1 = CacheKey::of(&g1, DeviceId::Xeon6126, FP);
        let mut k2 = CacheKey::of(&g2, DeviceId::Xeon6126, FP);
        if k1.graph == k2.graph {
            continue; // same structure drawn twice: nothing to force
        }
        // adversary forces the FNV half and defeats the node-count
        // tripwire; only graph2 is left to tell the graphs apart
        k2.graph = k1.graph;
        k2.nodes = k1.nodes;
        assert_ne!(k1, k2, "seed {seed}: forced FNV collision aliased the key");
        assert_ne!(k1.graph2, k2.graph2, "seed {seed}: second digest collided too");
        checked += 1;
    }
    assert!(checked >= CASES / 2, "too few distinct pairs exercised ({checked})");
}

/// PROPERTY: the optimizer's schedule covers all compute — effective FLOPs
/// are positive, no kernel exceeds the whole graph's raw FLOPs, and fusing
/// never increases HBM traffic.
#[test]
fn prop_optimizer_schedule_invariants() {
    for seed in 0..CASES as u64 {
        let mut rng = XorShift::new(seed + 900);
        let g = random_graph(&mut rng);
        if g.flops() == 0 {
            continue;
        }
        for dev in [DeviceId::Xeon6126, DeviceId::TitanV] {
            let mut opts = OptimizeOptions::new(dev);
            let fused = optimize(&g, &opts);
            opts.enable_fusion = false;
            let unfused = optimize(&g, &opts);
            assert!(fused.total_flops() > 0, "seed {seed}");
            assert!(
                fused.kernel_count() <= unfused.kernel_count(),
                "seed {seed}: fusion increased kernel count"
            );
            assert!(
                fused.total_hbm_bytes() <= unfused.total_hbm_bytes(),
                "seed {seed}: fusion increased traffic"
            );
        }
    }
}

/// PROPERTY: the optimized (im2col + blocked-GEMM / tiled) kernels equal
/// the naive reference kernels bit-tolerantly (≤ 1e-4 relative) over
/// randomized shapes, strides, pads and groups — including depthwise.
#[test]
fn prop_fast_kernels_match_naive() {
    let naive = install_default();
    let mut fast = install_default();
    register_cpu_fast_kernels(&mut fast, 1);
    let rel_close = |seed: u64, a: &[f32], b: &[f32]| {
        assert_eq!(a.len(), b.len(), "seed {seed}: shape drift");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-4 * (1.0 + x.abs().max(y.abs())),
                "seed {seed} elem {i}: {x} vs {y}"
            );
        }
    };
    for seed in 0..CASES as u64 {
        let mut rng = XorShift::new(seed + 5000);
        // conv2d: random channels/kernel/stride/pad/groups (valid combos)
        let groups = *rng.pick(&[1usize, 1, 2, 4]);
        let cing = *rng.pick(&[1usize, 2, 3]);
        let cin = cing * groups;
        let cpg_out = *rng.pick(&[1usize, 2, 3]);
        let cout = cpg_out * groups;
        let k = *rng.pick(&[1usize, 3, 5]);
        let stride = *rng.pick(&[1usize, 1, 2]);
        let pad = rng.below(k); // pad < k keeps output well-defined
        let hw = *rng.pick(&[7usize, 9, 12]);
        if hw + 2 * pad < k {
            continue;
        }
        let n = *rng.pick(&[1usize, 2]);
        let x = Tensor::randn(&[n, cin, hw, hw], seed + 5100, 0.5);
        let w = Tensor::randn(&[cout, cing, k, k], seed + 5200, 0.5);
        let b = Tensor::randn(&[cout], seed + 5300, 0.5);
        let attrs = Attrs::new()
            .with_int("stride", stride as i64)
            .with_int("pad", pad as i64)
            .with_int("groups", groups as i64);
        let inputs = [x, w, b];
        let want = naive
            .dispatch("aten::conv2d", DeviceType::Cpu, &inputs, &attrs)
            .unwrap();
        let got = fast
            .dispatch("aten::conv2d", DeviceType::Cpu, &inputs, &attrs)
            .unwrap();
        assert_eq!(want.shape, got.shape, "seed {seed}");
        rel_close(seed, &want.to_f32().unwrap(), &got.to_f32().unwrap());

        // linear: random (n, in, out) including non-multiple-of-8 widths
        let (nb, fin, fout) = (rng.range(1, 5), rng.range(1, 70), rng.range(1, 40));
        let x = Tensor::randn(&[nb, fin], seed + 5400, 0.5);
        let w = Tensor::randn(&[fout, fin], seed + 5500, 0.5);
        let b = Tensor::randn(&[fout], seed + 5600, 0.5);
        let inputs = [x, w, b];
        let want = naive
            .dispatch("aten::linear", DeviceType::Cpu, &inputs, &Attrs::new())
            .unwrap();
        let got = fast
            .dispatch("aten::linear", DeviceType::Cpu, &inputs, &Attrs::new())
            .unwrap();
        rel_close(seed, &want.to_f32().unwrap(), &got.to_f32().unwrap());
    }
}

/// Independent last-reader recomputation over the plan's alias classes:
/// class `r`'s buffer is live over `[r, last reader of any member]`.
fn live_ranges(g: &Graph, rep: &[usize]) -> Vec<usize> {
    let n = g.nodes.len();
    let mut last = (0..n).collect::<Vec<_>>();
    for node in &g.nodes {
        for &i in &node.inputs {
            last[rep[i]] = last[rep[i]].max(node.id);
        }
    }
    last[rep[g.output()]] = usize::MAX;
    last
}

/// PROPERTY: the memory planner never assigns one slot to two buffers
/// whose live ranges overlap, only aliases where in-place is legal,
/// sizes every slot for its largest tenant, and reports a consistent
/// arena total.
#[test]
fn prop_planner_slots_never_overlap() {
    for seed in 0..CASES as u64 {
        let mut rng = XorShift::new(seed + 6000);
        let g = random_graph(&mut rng);
        let plan = plan_memory(&g);
        assert_eq!(plan.node_slot.len(), g.nodes.len(), "seed {seed}");
        assert_eq!(
            plan.arena_bytes,
            plan.slot_bytes.iter().sum::<usize>(),
            "seed {seed}: arena total inconsistent"
        );
        assert!(plan.live_peak_bytes <= plan.arena_bytes, "seed {seed}");
        let rep = &plan.alias_of;
        let last = live_ranges(&g, rep);
        for node in &g.nodes {
            let id = node.id;
            // alias legality: only view ops and ReLU may share a buffer,
            // chains are fully resolved, and members share the slot
            if rep[id] != id {
                assert!(
                    matches!(node.op, Op::Flatten | Op::Dropout | Op::ReLU),
                    "seed {seed}: {:?} aliased",
                    node.op
                );
                assert_eq!(rep[rep[id]], rep[id], "seed {seed}: alias chain not resolved");
                assert_eq!(plan.node_slot[id], plan.node_slot[rep[id]], "seed {seed} node {id}");
                // an in-place ReLU must be the final reader of the
                // pre-clamp contents: nobody may read a value defined
                // before the relu (same buffer) after the relu ran —
                // readers of the relu's own output see post-clamp data
                // and are fine
                if matches!(node.op, Op::ReLU) {
                    for other in &g.nodes {
                        let stale_read = other.id > id
                            && other.inputs.iter().any(|&i| rep[i] == rep[id] && i < id);
                        assert!(
                            !stale_read,
                            "seed {seed}: node {} reads pre-clamp data of in-place relu {id}",
                            other.id
                        );
                    }
                }
            }
            assert!(
                plan.slot_bytes[plan.node_slot[id]] >= node.meta.bytes(),
                "seed {seed} node {id}: slot too small"
            );
        }
        for a in 0..g.nodes.len() {
            if rep[a] != a {
                continue;
            }
            for b in (a + 1)..g.nodes.len() {
                if rep[b] != b || plan.node_slot[a] != plan.node_slot[b] {
                    continue;
                }
                // shared slot ⇒ live ranges [a, last[a]] and [b, last[b]]
                // must be disjoint (b > a, so a must die before b is born)
                assert!(
                    last[a] < b,
                    "seed {seed}: buffers {a} (live to {}) and {b} share slot {}",
                    last[a],
                    plan.node_slot[a]
                );
            }
        }
    }
}

/// PROPERTY: the transfer planner conserves bytes, preserves direction
/// within every packed segment, and never packs a large tensor.
#[test]
fn prop_memcpy_planner() {
    for seed in 0..200u64 {
        let mut rng = XorShift::new(seed + 1300);
        let reqs: Vec<Transfer> = (0..rng.range(0, 40))
            .map(|_| Transfer {
                bytes: *rng.pick(&[64usize, 4096, 100_000, 1 << 20, 600 << 10]),
                to_device: rng.below(2) == 0,
            })
            .collect();
        let plans = plan_transfers(&reqs);
        let total: usize = plans.iter().map(|p| p.total_bytes()).sum();
        assert_eq!(total, reqs.iter().map(|t| t.bytes).sum::<usize>(), "seed {seed}");
        for p in &plans {
            if let TransferPlan::Packed { transfers, .. } = p {
                assert!(transfers.len() >= 3, "seed {seed}: packed too few");
                let dir = transfers[0].to_device;
                assert!(transfers.iter().all(|t| t.to_device == dir), "seed {seed}");
                assert!(
                    transfers.iter().all(|t| t.bytes < 256 * 1024),
                    "seed {seed}: large tensor packed"
                );
            }
        }
    }
}

/// PROPERTY: DeviceMemory never double-books bytes; used == sum(live);
/// alloc-after-free reuses space (no unbounded growth under churn).
#[test]
fn prop_device_memory_churn() {
    for seed in 0..60u64 {
        let mut rng = XorShift::new(seed + 1700);
        let mut mem = DeviceMemory::new(1 << 22);
        let mut live: Vec<(u64, u64)> = Vec::new();
        let mut expected_used = 0u64;
        for _ in 0..300 {
            if live.is_empty() || rng.below(5) < 3 {
                let size = rng.range(1, 60_000) as u64;
                if let Ok(base) = mem.alloc(size) {
                    let aligned = size.max(1).next_multiple_of(64);
                    live.push((base, aligned));
                    expected_used += aligned;
                }
            } else {
                let idx = rng.below(live.len());
                let (base, size) = live.swap_remove(idx);
                mem.free(base).unwrap();
                expected_used -= size;
            }
            assert_eq!(mem.used, expected_used, "seed {seed}");
        }
        // no overlap among live regions
        let mut regions = live.clone();
        regions.sort();
        for w in regions.windows(2) {
            assert!(w[0].0 + w[0].1 <= w[1].0, "seed {seed}: overlap");
        }
    }
}

/// PROPERTY: async queue executes everything exactly once, in order, for
/// arbitrary interleavings of malloc/free/work/sync.
#[test]
fn prop_queue_linearizes() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    for seed in 0..30u64 {
        let mut rng = XorShift::new(seed + 2100);
        let q = AsyncQueue::new(1 << 24);
        let counter = Arc::new(AtomicUsize::new(0));
        let mut ptrs: Vec<VirtualPtr> = Vec::new();
        let mut submitted = 0usize;
        for _ in 0..rng.range(10, 120) {
            match rng.below(4) {
                0 => ptrs.push(q.malloc_async(rng.range(64, 4096) as u64)),
                1 if !ptrs.is_empty() => {
                    let p = ptrs.swap_remove(rng.below(ptrs.len()));
                    q.free_async(p);
                }
                2 if !ptrs.is_empty() => {
                    let p = *rng.pick(&ptrs);
                    let c = counter.clone();
                    let expect = submitted;
                    submitted += 1;
                    q.submit_with_ptrs(vec![p], move |addrs| {
                        assert!(!addrs.is_empty());
                        let prev = c.fetch_add(1, Ordering::SeqCst);
                        assert_eq!(prev, expect, "out of order");
                    });
                }
                _ => {
                    let c = counter.clone();
                    let expect = submitted;
                    submitted += 1;
                    q.submit(move || {
                        let prev = c.fetch_add(1, Ordering::SeqCst);
                        assert_eq!(prev, expect, "out of order");
                    });
                }
            }
        }
        q.sync().unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), submitted, "seed {seed}");
    }
}

/// PROPERTY: JSON writer/parser round-trips arbitrary values.
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut XorShift, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(100000) as f64) - 5000.0),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| *rng.pick(&['a', 'ü', '"', '\\', '\n', 'z'])).collect())
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..300u64 {
        let mut rng = XorShift::new(seed + 2500);
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(v, back, "seed {seed}");
    }
}

/// PROPERTY: cost model is monotone — more flops or more bytes never makes
/// a kernel faster.
#[test]
fn prop_cost_monotone() {
    let t = EfficiencyTable::default();
    for seed in 0..200u64 {
        let mut rng = XorShift::new(seed + 3000);
        let spec = DeviceId::ALL[rng.below(4)].spec();
        let class = *rng.pick(&[
            sol::devsim::KernelClass::LibraryMatmul,
            sol::devsim::KernelClass::DfpFused,
            sol::devsim::KernelClass::Elementwise,
        ]);
        let f = rng.range(1, 1 << 26);
        let b = rng.range(1, 1 << 24);
        let frac = 0.1 + 0.9 * rng.f32() as f64;
        let base = t.kernel_us(&spec, class, f, b, frac);
        assert!(t.kernel_us(&spec, class, f * 2, b, frac) >= base, "seed {seed}");
        assert!(t.kernel_us(&spec, class, f, b * 2, frac) >= base, "seed {seed}");
    }
}
