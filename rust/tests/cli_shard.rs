//! CLI tests for `sol shard --json`: the machine-readable placement
//! report is the deployment-facing contract (per-shard device, cost,
//! transfer bytes, memory fit), so its shape and deterministic values
//! must change deliberately.
//!
//! The golden pins the zoo-net planning path (fully deterministic: no
//! execution, simulator-priced estimates only).  Comparison is over
//! *parsed* JSON.  The first run writes the golden if it does not exist
//! yet (commit it); after an intentional change re-bless with
//! `BLESS=1 cargo test --test cli_shard`.

use std::path::PathBuf;
use std::process::Command;

use sol::util::Json;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/sol_shard.json")
}

fn run_shard(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_sol"))
        .arg("shard")
        .args(args)
        .output()
        .expect("run sol shard")
}

/// The golden invocation: plan-only (no equivalence floats), forced
/// depth, fixed two-device registry — every value is deterministic.
const GOLDEN_ARGS: &[&str] =
    &["--json", "--net", "mlp", "--batch", "4", "--devices", "cpu,titanv", "--stages", "2"];

#[test]
fn sol_shard_json_matches_golden() {
    let out = run_shard(GOLDEN_ARGS);
    assert!(out.status.success(), "sol shard failed: {out:?}");
    let stdout = String::from_utf8(out.stdout).expect("utf8 stdout");
    if std::env::var_os("BLESS").is_some() || !golden_path().exists() {
        std::fs::write(golden_path(), &stdout).expect("bless golden");
        return;
    }
    let got = Json::parse(&stdout).expect("shard stdout parses as JSON");
    let want = Json::parse(&std::fs::read_to_string(golden_path()).expect("read golden"))
        .expect("golden parses as JSON");
    assert_eq!(
        got, want,
        "`sol shard {}` drifted from the golden report \
         (rust/tests/golden/sol_shard.json) — re-bless with BLESS=1 if intentional",
        GOLDEN_ARGS.join(" ")
    );
}

#[test]
fn sol_shard_json_has_the_placement_contract_shape() {
    let out = run_shard(GOLDEN_ARGS);
    assert!(out.status.success(), "sol shard failed: {out:?}");
    let doc = Json::parse(&String::from_utf8(out.stdout).unwrap()).unwrap();
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("shard"));
    assert_eq!(doc.get("mode").and_then(Json::as_str), Some("full"));
    // zoo nets are planned and priced, not executed
    assert_eq!(doc.get("equivalence"), Some(&Json::Null));

    let plan = doc.get("plan").expect("plan object");
    let stages = plan.get("stages").and_then(Json::as_arr).expect("stages array");
    assert_eq!(stages.len(), 2, "forced depth 2");
    for (i, s) in stages.iter().enumerate() {
        assert_eq!(s.get("index").and_then(Json::as_f64), Some(i as f64));
        let dev = s.get("device").and_then(Json::as_str).expect("stage device");
        assert!(
            dev == "Xeon6126" || dev == "TitanV",
            "stage {i} placed on unrequested device {dev}"
        );
        // every shard fits its device's memory capacity
        assert_eq!(s.get("mem_fit"), Some(&Json::Bool(true)), "stage {i} must fit");
        let req = s.get("mem_required").and_then(Json::as_f64).unwrap();
        let cap = s.get("mem_capacity").and_then(Json::as_f64).unwrap();
        assert!(req > 0.0 && req <= cap, "stage {i}: {req} B of {cap} B");
        assert!(s.get("est_us").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(s.get("flops").and_then(Json::as_f64).unwrap() > 0.0);
    }

    // boundaries are priced end to end, host feed to host drain
    let transfers = plan.get("transfers").and_then(Json::as_arr).expect("transfers");
    assert!(transfers.len() >= 3, "host-in, inter-stage and host-out edges");
    assert_eq!(transfers.first().unwrap().get("from").and_then(Json::as_str), Some("host"));
    assert_eq!(transfers.last().unwrap().get("to").and_then(Json::as_str), Some("host"));
    for t in transfers {
        assert!(t.get("bytes").and_then(Json::as_f64).unwrap() > 0.0);
    }

    // the single-device bound is present, and a losing forced-depth plan
    // must explain itself
    let single = plan.get("single_device").expect("single_device");
    assert!(single.get("est_us").and_then(Json::as_f64).unwrap() > 0.0);
    let beats = match plan.get("beats_single") {
        Some(Json::Bool(b)) => *b,
        other => panic!("beats_single must be a bool, got {other:?}"),
    };
    if !beats {
        assert!(
            plan.get("reason").and_then(Json::as_str).is_some(),
            "a losing plan must carry a reason"
        );
    }
    assert!(plan.get("est_total_us").and_then(Json::as_f64).unwrap() > 0.0);
}

#[test]
fn sol_shard_smoke_executes_fig3_and_verifies_equivalence() {
    // the CI shard-smoke gate: plans fig3 over the fixed two-device
    // registry, runs the staged plan, and exits 2 on divergence
    let out = run_shard(&["--smoke", "--json"]);
    assert!(out.status.success(), "sol shard --smoke failed: {out:?}");
    let doc = Json::parse(&String::from_utf8(out.stdout).unwrap()).unwrap();
    assert_eq!(doc.get("mode").and_then(Json::as_str), Some("smoke"));
    let eq = doc.get("equivalence").expect("fig3 runs the equivalence check");
    assert_eq!(eq.get("ok"), Some(&Json::Bool(true)), "sharded fig3 diverged: {doc:?}");
    assert!(eq.get("checked").and_then(Json::as_f64).unwrap() > 0.0);
    let stages = doc.get("plan").unwrap().get("stages").and_then(Json::as_arr).unwrap();
    assert!(stages.iter().all(|s| s.get("mem_fit") == Some(&Json::Bool(true))));
}

#[test]
fn sol_shard_rejects_unknown_devices_and_nets() {
    let out = run_shard(&["--devices", "cpu,warp9"]);
    assert!(!out.status.success(), "unknown device must fail");
    let out = run_shard(&["--net", "not-a-net"]);
    assert!(!out.status.success(), "unknown net must fail");
}
