//! Cross-layer tests for the session subsystem: pass-manager ablation
//! equivalence, graph-hash stability, compile-cache behaviour, backend
//! registry lookups, and the `fig3_row` output contract for the default
//! pipeline.

use std::sync::Arc;

use sol::backends::BackendRegistry;
use sol::devsim::{DeviceId, EfficiencyTable, SimEngine};
use sol::exec::baseline::{baseline_infer_steps, baseline_train_steps, BaselineKind};
use sol::exec::fig3::fig3_row;
use sol::exec::solrun::{sol_infer_steps, sol_train_steps, OffloadMode};
use sol::framework::DeviceType;
use sol::passes::{optimize, OptimizeOptions, OptimizedModel, Step};
use sol::session::{PassManager, Phase, PipelineConfig, Session};
use sol::workloads::NetId;

/// Structural equality of two compiled schedules.
fn assert_models_equivalent(a: &OptimizedModel, b: &OptimizedModel) {
    assert_eq!(a.device, b.device);
    assert_eq!(a.elided_layers, b.elided_layers);
    assert_eq!(a.autotune_us, b.autotune_us);
    assert_eq!(a.param_bytes, b.param_bytes);
    assert_eq!(a.input_bytes, b.input_bytes);
    assert_eq!(a.output_bytes, b.output_bytes);
    assert_eq!(a.layout.reorders, b.layout.reorders);
    assert_eq!(a.steps.len(), b.steps.len());
    for (x, y) in a.steps.iter().zip(&b.steps) {
        match (x, y) {
            (Step::Kernel(k1), Step::Kernel(k2)) => {
                assert_eq!(k1.name, k2.name);
                assert_eq!(k1.class, k2.class);
                assert_eq!(k1.flops, k2.flops);
                assert_eq!(k1.hbm_bytes, k2.hbm_bytes);
                assert_eq!(k1.parallel_fraction, k2.parallel_fraction);
            }
            (Step::Reorder { bytes: b1 }, Step::Reorder { bytes: b2 }) => {
                assert_eq!(b1, b2);
            }
            other => panic!("step kind mismatch: {other:?}"),
        }
    }
}

#[test]
fn pipeline_with_elide_off_equals_legacy_elision_flag() {
    for dev in [DeviceId::Xeon6126, DeviceId::AuroraVE10B] {
        let g = NetId::Vgg16.build(1);
        // legacy flag-bag ablation
        let mut opts = OptimizeOptions::new(dev);
        opts.enable_elision = false;
        let legacy = optimize(&g, &opts);
        // pass-toggle ablation
        let mut cfg = PipelineConfig::new(dev);
        cfg.disable_pass("elide");
        let toggled = PassManager::standard(cfg).compile(&g).unwrap();
        assert_models_equivalent(&legacy, &toggled);
        assert_eq!(toggled.elided_layers, 0);
    }
}

#[test]
fn fusion_config_matches_legacy_flag() {
    let g = NetId::Resnet18.build(1);
    let mut opts = OptimizeOptions::new(DeviceId::Xeon6126);
    opts.enable_fusion = false;
    let legacy = optimize(&g, &opts);
    let mut cfg = PipelineConfig::new(DeviceId::Xeon6126);
    cfg.enable_fusion = false;
    let configured = PassManager::standard(cfg).compile(&g).unwrap();
    assert_models_equivalent(&legacy, &configured);
}

#[test]
fn optimize_is_a_pass_manager_wrapper() {
    // identical output through the wrapper and the manager directly
    for net in [NetId::Resnet50, NetId::ShufflenetV2X1_0, NetId::Mlp] {
        let g = net.build(1);
        let wrapped = optimize(&g, &OptimizeOptions::new(DeviceId::TitanV));
        let direct = PassManager::standard(PipelineConfig::new(DeviceId::TitanV))
            .compile(&g)
            .unwrap();
        assert_models_equivalent(&wrapped, &direct);
        // and the wrapper carries per-pass records of exactly the pass
        // list the device's backend composed (API v2: the GPU backends
        // run the seven core stages, host-CPU adds plan-memory)
        let want: Vec<&str> =
            sol::backends::default_registry().pipeline_names_for(DeviceId::TitanV);
        let got: Vec<&str> = wrapped.pass_records.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(got, want);
        assert_eq!(wrapped.pass_records.len(), 7);
        assert!(wrapped.pass_records.iter().all(|r| !r.skipped));
    }
}

#[test]
fn graph_hash_stable_across_rebuilds_and_names() {
    for net in [NetId::Resnet18, NetId::Densenet121, NetId::Mlp] {
        let h1 = net.build(1).structural_hash();
        let h2 = net.build(1).structural_hash();
        assert_eq!(h1, h2, "{}: rebuild changed the hash", net.name());
        let mut renamed = net.build(1);
        renamed.name = "something-else".into();
        for n in &mut renamed.nodes {
            n.name = format!("n{}", n.id);
        }
        assert_eq!(h1, renamed.structural_hash(), "{}: names leaked into hash", net.name());
        assert_ne!(h1, net.build(2).structural_hash(), "{}: batch must change hash", net.name());
    }
}

#[test]
fn graph_hash_collision_sanity_across_the_zoo() {
    // all 13 nets at two batch sizes: 26 distinct structures, 0 collisions
    let mut hashes = std::collections::HashSet::new();
    for net in NetId::ALL {
        for b in [1, net.training_batch()] {
            hashes.insert(net.build(b).structural_hash());
        }
    }
    assert_eq!(hashes.len(), 2 * NetId::ALL.len());
}

#[test]
fn second_compile_is_a_cache_hit_with_counters() {
    let session = Session::new();
    let g = NetId::Resnet18.build(1);
    let first = session.compile(&g, DeviceId::AuroraVE10B);
    assert_eq!(session.cache().misses(), 1, "first compile must miss");
    assert_eq!(session.cache().hits(), 0);
    let second = session.compile(&g, DeviceId::AuroraVE10B);
    assert_eq!(session.cache().misses(), 1, "second compile must not recompile");
    assert_eq!(session.cache().hits(), 1, "second compile must hit");
    assert!(Arc::ptr_eq(&first, &second), "hit must return the same artifact");
    // another device is another content address
    session.compile(&g, DeviceId::Xeon6126);
    assert_eq!((session.cache().hits(), session.cache().misses()), (1, 2));
    assert_eq!(session.cache().len(), 2);
}

#[test]
fn cache_counters_reach_the_metrics_registry() {
    let hit0 = sol::metrics::counter("compile_cache.hit").get();
    let miss0 = sol::metrics::counter("compile_cache.miss").get();
    let session = Session::new();
    let g = NetId::Squeezenet1_0.build(1);
    session.compile(&g, DeviceId::TitanV);
    session.compile(&g, DeviceId::TitanV);
    assert!(sol::metrics::counter("compile_cache.hit").get() >= hit0 + 1);
    assert!(sol::metrics::counter("compile_cache.miss").get() >= miss0 + 1);
}

#[test]
fn backend_registry_roundtrips() {
    let r = BackendRegistry::with_defaults();
    assert_eq!(r.len(), 5);
    assert_eq!(r.devices().len(), 4, "arm64 shares the CPU device model");
    for b in r.iter() {
        // name -> backend roundtrip
        let by_name = r.by_name(b.name()).expect("every backend resolvable by name");
        assert_eq!(by_name.device(), b.device());
        // device -> backend resolves to a backend of that device
        let by_dev = r.by_device(b.device()).expect("every device resolvable");
        assert_eq!(by_dev.device(), b.device());
    }
    // framework-slot lookup: only the Aurora squats on HIP (§V-B)
    let hip = r.by_framework_slot(DeviceType::Hip);
    assert_eq!(hip.len(), 1);
    assert_eq!(hip[0].device(), DeviceId::AuroraVE10B);
    // unknown lookups are clean misses
    assert!(r.by_name("tpu-v9").is_none());
    // session exposes the same registry
    assert_eq!(Session::new().registry().len(), 5);
}

/// The acceptance contract: `fig3_row` through Session/Executor must equal
/// the legacy hand-rolled computation for the default pipeline, bit for bit.
#[test]
fn fig3_row_output_unchanged_for_default_pipeline() {
    let eff = EfficiencyTable::default();
    for (net, dev, training) in [
        (NetId::Resnet18, DeviceId::Xeon6126, false),
        (NetId::Resnet50, DeviceId::AuroraVE10B, false),
        (NetId::Vgg16, DeviceId::TitanV, true),
        (NetId::Mlp, DeviceId::Xeon6126, true),
        (NetId::ShufflenetV2X0_5, DeviceId::AuroraVE10B, false),
    ] {
        let row = fig3_row(net, dev, training, &eff);

        // --- the legacy computation, reconstructed inline ---
        let b = if training { net.training_batch() } else { 1 };
        let g = net.build(b);
        let kind = BaselineKind::for_device(dev);
        let want_baseline = if kind == BaselineKind::TfVe && !net.supported_by_tfve() {
            None
        } else {
            let eng = SimEngine::new(dev.spec(), eff.clone(), kind.async_queue(dev));
            let steps = if training {
                baseline_train_steps(&g, dev, kind, &eff)
            } else {
                baseline_infer_steps(&g, dev, kind, &eff)
            };
            Some(eng.run(&steps).total_ms())
        };
        let mut opts = OptimizeOptions::new(dev);
        opts.eff = eff.clone();
        let model = optimize(&g, &opts);
        let eng = SimEngine::new(dev.spec(), eff.clone(), true);
        let (want_sol, want_to) = if training {
            (
                eng.run(&sol_train_steps(&model, OffloadMode::Native)).total_ms(),
                eng.run(&sol_train_steps(&model, OffloadMode::Transparent)).total_ms(),
            )
        } else {
            (
                eng.run(&sol_infer_steps(&model, OffloadMode::Native, false)).total_ms(),
                eng.run(&sol_infer_steps(&model, OffloadMode::Transparent, false)).total_ms(),
            )
        };

        assert_eq!(row.baseline_ms, want_baseline, "{} {:?} baseline", net.name(), dev);
        assert_eq!(row.sol_ms, want_sol, "{} {:?} sol", net.name(), dev);
        assert_eq!(row.sol_to_ms, want_to, "{} {:?} sol-TO", net.name(), dev);
    }
}

#[test]
fn session_run_drives_all_executors() {
    let session = Session::new();
    let g = NetId::Squeezenet1_1.build(1);
    let dev = DeviceId::AuroraVE10B;
    let base = session.baseline_executor(g.clone(), dev);
    let model = session.compile(&g, dev);
    let sol = session.sol_executor(model.clone(), OffloadMode::Native);
    let to = session.sol_executor(model, OffloadMode::Transparent);
    let b = session.run(&base, Phase::infer()).total_us;
    let s = session.run(&sol, Phase::infer()).total_us;
    let t = session.run(&to, Phase::Infer { first_run: true }).total_us;
    assert!(b > 0.0 && s > 0.0 && t > 0.0);
    assert!(s < b, "SOL must beat the TF-VE baseline on the Aurora");
    assert!(t > s, "first TO run pays the parameter upload");
}
