//! Acceptance tests for the serving spine: request queue backpressure,
//! deadline rejection, dynamic same-artifact batching, batched-vs-
//! sequential numerical agreement, and the `BENCH_7.json` soak recording.
//!
//! This binary installs the counting allocator, so the spine's
//! zero-allocations-per-steady-run claim is measured at the allocator.
//! (The harness runs tests on several threads over one process-global
//! counter; alloc-delta checks therefore retry — one clean run proves
//! the path allocates nothing, while a real allocation would taint
//! every attempt.)

use std::sync::Arc;
use std::time::Duration;

use sol::audit::fixed_workloads;
use sol::devsim::DeviceId;
use sol::exec::kernelbench::validate_bench_json;
use sol::exec::servebench::{run_serve_bench, write_serve_bench_json, ServeBenchConfig};
use sol::frontend::{extract_graph, ArenaExec};
use sol::session::{AdmissionError, ServingConfig, ServingSession, SpineConfig, SpinePolicy};
use sol::util::alloc::alloc_count;
use sol::util::gen::random_module;
use sol::util::{Json, XorShift};

#[global_allocator]
static ALLOC: sol::util::alloc::CountingAllocator = sol::util::alloc::CountingAllocator;

fn assert_close(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + a.abs().max(b.abs())),
            "{ctx}: elem {i}: {a} vs {b}"
        );
    }
}

/// A manual-pump spine (no worker threads): every drain happens on the
/// test thread, so queue contents and batch composition are exact.
fn pump_spine(queue_depth: usize, max_batch: usize) -> ServingSession {
    let serving = ServingSession::new(ServingConfig::default());
    serving.spine_with(SpineConfig {
        workers: 0,
        queue_depth,
        max_batch,
        default_deadline: None,
        ..SpineConfig::default()
    });
    serving
}

/// Property: a batched arena execution is element-wise equal (≤ 1e-4
/// relative) to running the same requests one at a time, over random
/// modules and random batch sizes.
#[test]
fn batched_execution_matches_sequential_over_random_modules() {
    const CASES: u64 = 12;
    for seed in 0..CASES {
        let mut rng = XorShift::new(seed);
        let (module, shape) = random_module(&mut rng);
        let (graph, binding) = extract_graph(&module, &shape, "prop").unwrap();
        let unit = ArenaExec::build(&graph, &binding, 1).unwrap();
        let max_batch = 2 + (seed as usize % 3); // 2..=4
        let batched = ArenaExec::build_batched(&graph, &binding, 1, max_batch).unwrap();
        let k = 1 + rng.below(max_batch);
        let inputs: Vec<Vec<f32>> =
            (0..k).map(|_| rng.normal_vec(unit.input_len(), 0.5)).collect();
        let in_refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut outs: Vec<Vec<f32>> = (0..k).map(|_| Vec::new()).collect();
        batched.run_batch(&in_refs, &mut outs).unwrap();
        for (i, input) in inputs.iter().enumerate() {
            unit.run(input).unwrap();
            let mut want = Vec::new();
            unit.read_output(&mut want);
            assert_close(&outs[i], &want, &format!("seed {seed}, batch {k}, request {i}"));
        }
    }
}

/// The spine coalesces same-artifact requests across tenants into one
/// batch, leaves other artifacts queued in order, and every output
/// matches the synchronous path.
#[test]
fn spine_batches_same_artifact_across_tenants() {
    let serving = pump_spine(64, 4);
    let wls = fixed_workloads();
    let (g_cnn, b_cnn) = extract_graph(&wls[0].module, &wls[0].input_shape, "mini-cnn").unwrap();
    let (g_mlp, b_mlp) = extract_graph(&wls[2].module, &wls[2].input_shape, "mlp").unwrap();
    let alice = serving.tenant("alice");
    let bob = serving.tenant("bob");
    let cnn = alice.load_artifact(&g_cnn, &b_cnn, DeviceId::Xeon6126).unwrap();
    let cnn_again = bob.load_artifact(&g_cnn, &b_cnn, DeviceId::Xeon6126).unwrap();
    assert!(Arc::ptr_eq(&cnn, &cnn_again), "same content address, one served artifact");
    let mlp = bob.load_artifact(&g_mlp, &b_mlp, DeviceId::Xeon6126).unwrap();
    assert_ne!(cnn.key(), mlp.key());

    let mut rng = XorShift::new(5);
    let xc1 = rng.normal_vec(cnn.input_len(), 0.5);
    let xm = rng.normal_vec(mlp.input_len(), 0.5);
    let xc2 = rng.normal_vec(cnn.input_len(), 0.5);
    // queue order: cnn(alice), mlp(bob), cnn(bob)
    let h1 = alice.submit(&cnn, xc1.clone(), None).unwrap();
    let h2 = bob.submit(&mlp, xm.clone(), None).unwrap();
    let h3 = bob.submit(&cnn, xc2.clone(), None).unwrap();
    assert_eq!(serving.spine().stats().queued, 3);

    // first drain: both cnn requests coalesce past the queued mlp
    assert_eq!(serving.spine().drain_one(DeviceId::Xeon6126), 2);
    let o1 = h1.wait().unwrap();
    let o3 = h3.wait().unwrap();
    assert_eq!((o1.batch_size, o3.batch_size), (2, 2));
    assert!(!h2.is_done(), "the mlp request must still be queued");
    // second drain serves the mlp alone
    assert_eq!(serving.spine().drain_one(DeviceId::Xeon6126), 1);
    let o2 = h2.wait().unwrap();
    assert_eq!(o2.batch_size, 1);

    // batched outputs match the synchronous single-request path
    let mut want = Vec::new();
    cnn.run_blocking(&xc1, &mut want).unwrap();
    assert_close(&o1.output, &want, "cnn request 1");
    cnn.run_blocking(&xc2, &mut want).unwrap();
    assert_close(&o3.output, &want, "cnn request 2");
    mlp.run_blocking(&xm, &mut want).unwrap();
    assert_close(&o2.output, &want, "mlp request");

    let st = serving.spine().stats();
    assert_eq!((st.submitted, st.completed, st.batches, st.queued), (3, 3, 2, 0));
    assert!(st.batch_max >= 2, "the coalesced pair must register");
    // completed submissions are attributed to the submitting tenant
    assert_eq!(alice.counters().runs, 1);
    assert_eq!(bob.counters().runs, 2);
    // the serving report surfaces the spine
    let report = serving.serving_report();
    assert!(report.contains("spine: 0 workers"), "{report}");
}

/// Backpressure: the bounded queue rejects at its depth — deterministic
/// with the manual pump — and frees up once drained.
#[test]
fn queue_full_rejects_at_the_bound() {
    let serving = pump_spine(2, 2);
    let wl = &fixed_workloads()[2]; // mlp, the smallest fixed workload
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mlp").unwrap();
    let t = serving.tenant("pressured");
    let art = t.load_artifact(&g, &b, DeviceId::Xeon6126).unwrap();
    let x = vec![0.1f32; art.input_len()];
    let h1 = t.submit(&art, x.clone(), None).unwrap();
    let h2 = t.submit(&art, x.clone(), None).unwrap();
    let err = t.submit(&art, x.clone(), None).unwrap_err();
    assert_eq!(err, AdmissionError::QueueFull { device: DeviceId::Xeon6126, depth: 2 });
    let st = serving.spine().stats();
    assert_eq!((st.rejected_full, st.submitted), (1, 2));
    // draining frees the bound; the rejected submit succeeds on retry
    assert_eq!(serving.spine().drain_device(DeviceId::Xeon6126), 2);
    assert!(h1.wait().is_ok() && h2.wait().is_ok());
    let h = t.submit(&art, x, None).unwrap();
    serving.spine().drain_one(DeviceId::Xeon6126);
    assert!(h.wait().is_ok());
}

/// A request whose deadline passes *while queued* is rejected with
/// `DeadlineExceeded` at drain time — completed, never silently dropped.
/// (A deadline already dead at submit never reaches a queue at all —
/// see `tests/spine_policy.rs` — so this test expires its request with
/// the spine's virtual clock instead of sleeping.)
#[test]
fn expired_requests_are_rejected_never_dropped() {
    let serving = pump_spine(8, 4);
    let wl = &fixed_workloads()[2];
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mlp").unwrap();
    let t = serving.tenant("deadline");
    let art = t.load_artifact(&g, &b, DeviceId::Xeon6126).unwrap();
    let x = vec![0.2f32; art.input_len()];
    let expired = t.submit(&art, x.clone(), Some(Duration::from_millis(2))).unwrap();
    let live = t.submit(&art, x, None).unwrap();
    // step past the 2ms deadline on the virtual clock — deterministic,
    // no sleeps
    serving.spine().advance_clock_us(5_000);
    // the drain *handles* both: one rejected, one fulfilled in a batch of 1
    assert_eq!(serving.spine().drain_one(DeviceId::Xeon6126), 2);
    match expired.wait() {
        Err(AdmissionError::DeadlineExceeded { waited_us }) => {
            assert!(waited_us >= 5_000, "waited {waited_us} µs, clock advanced 5 ms");
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    let out = live.wait().expect("undeadlined request still served");
    assert_eq!(out.batch_size, 1, "the expired request must not count in the batch");
    let st = serving.spine().stats();
    assert_eq!((st.expired, st.completed), (1, 1));
}

/// Spine batching needs an arena-capable backend; pure-simulation
/// devices are rejected at load, not at first drain.
#[test]
fn non_arena_backends_cannot_load_spine_artifacts() {
    let serving = pump_spine(8, 2);
    let wl = &fixed_workloads()[2];
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mlp").unwrap();
    let t = serving.tenant("aurora");
    let err = t.load_artifact(&g, &b, DeviceId::AuroraVE10B).unwrap_err();
    // typed as Unsupported — a *permanent* rejection callers must be
    // able to tell apart from transient QueueFull/Failed conditions
    assert!(
        matches!(
            &err,
            AdmissionError::Unsupported { device: DeviceId::AuroraVE10B, reason }
                if reason.contains("arena")
        ),
        "{err}"
    );
}

/// The per-artifact executor pool: construction seeds one executor, a
/// drain borrows and returns it, so repeated drains build nothing new.
#[test]
fn artifact_executor_pool_reuses_across_drains() {
    let serving = pump_spine(16, 2);
    let wl = &fixed_workloads()[2];
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mlp").unwrap();
    let t = serving.tenant("pool");
    let art = t.load_artifact(&g, &b, DeviceId::Xeon6126).unwrap();
    assert_eq!(art.pooled_execs(), 1, "load seeds the pool");
    let x = vec![0.3f32; art.input_len()];
    for _ in 0..4 {
        let h = t.submit(&art, x.clone(), None).unwrap();
        serving.spine().drain_one(DeviceId::Xeon6126);
        h.wait().unwrap();
        assert_eq!(art.pooled_execs(), 1, "the executor returns to the pool");
    }
}

/// End to end with real worker threads: every concurrent submission
/// completes with the right numbers, no pumping required.
#[test]
fn worker_pool_completes_concurrent_submissions() {
    let serving = ServingSession::new(ServingConfig::default());
    serving.spine_with(SpineConfig {
        workers: 2,
        queue_depth: 256,
        max_batch: 4,
        default_deadline: None,
        ..SpineConfig::default()
    });
    let wl = &fixed_workloads()[0]; // mini-cnn
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mini-cnn").unwrap();
    let a = serving.tenant("a");
    let z = serving.tenant("z");
    let art = a.load_artifact(&g, &b, DeviceId::Xeon6126).unwrap();
    let mut rng = XorShift::new(9);
    let inputs: Vec<Vec<f32>> =
        (0..32).map(|_| rng.normal_vec(art.input_len(), 0.5)).collect();
    let handles: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, x)| {
            let tenant = if i % 2 == 0 { &a } else { &z };
            tenant.submit(&art, x.clone(), None).unwrap()
        })
        .collect();
    let mut want = Vec::new();
    for (i, (h, x)) in handles.into_iter().zip(&inputs).enumerate() {
        let out = h.wait().expect("workers must complete every request");
        assert!(out.batch_size >= 1 && out.batch_size <= 4);
        art.run_blocking(x, &mut want).unwrap();
        assert_close(&out.output, &want, &format!("request {i}"));
    }
    let st = serving.spine().stats();
    assert_eq!((st.submitted, st.completed, st.queued), (32, 32, 0));
    assert_eq!(a.counters().runs + z.counters().runs, 32);
}

/// Acceptance: a warm spine batch performs zero heap allocations on the
/// run path, measured at the allocator.
#[test]
fn warm_spine_batches_allocate_nothing_on_the_run_path() {
    let serving = pump_spine(16, 4);
    let wl = &fixed_workloads()[0];
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mini-cnn").unwrap();
    let t = serving.tenant("alloc");
    let art = t.load_artifact(&g, &b, DeviceId::Xeon6126).unwrap();
    let input = vec![0.4f32; art.input_len()];
    let ins: Vec<Vec<f32>> = (0..4).map(|_| input.clone()).collect();
    let in_refs: Vec<&[f32]> = ins.iter().map(|v| v.as_slice()).collect();
    let mut outs: Vec<Vec<f32>> =
        (0..4).map(|_| Vec::with_capacity(art.output_len())).collect();
    art.run_batch_blocking(&in_refs, &mut outs).unwrap(); // warm
    let mut deltas = Vec::new();
    let mut clean = false;
    for _ in 0..20 {
        let a0 = alloc_count();
        art.run_batch_blocking(&in_refs, &mut outs).unwrap();
        let delta = alloc_count() - a0;
        deltas.push(delta);
        if delta == 0 {
            clean = true;
            break;
        }
    }
    assert!(
        clean,
        "no allocation-free warm batch in 20 attempts (deltas {deltas:?}) — \
         the spine's batched run path allocates"
    );
}

/// The smoke soak runs end to end and records `BENCH_7.json` under the
/// same schema gate as every other recorded benchmark.
#[test]
fn serve_bench_smoke_writes_bench_7_json() {
    let cfg = ServeBenchConfig {
        smoke: true,
        tenants: 6,
        requests: 48,
        workers: 2,
        max_batch: 4,
        policy: SpinePolicy::Fifo,
    };
    let r = run_serve_bench(&cfg).expect("smoke soak");
    assert!(r.sequential_rps > 0.0 && r.batched_rps > 0.0);
    assert!(r.batch_speedup > 0.0);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_7.json");
    write_serve_bench_json(&path, &r).unwrap();
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    validate_bench_json(&doc).expect("written BENCH_7.json validates");
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("serving-spine"));
    assert_eq!(doc.get("mode").and_then(Json::as_str), Some("smoke"));
    assert!(doc.get("batch_speedup").and_then(Json::as_f64).unwrap() > 0.0);
    let rows = doc.get("rows").and_then(Json::as_arr).unwrap();
    assert_eq!(rows.len(), 3);
    for row in rows {
        assert!(row.get("ns_per_iter").and_then(Json::as_f64).unwrap() > 0.0, "{row:?}");
    }
}

/// The full soak: thousands of logical tenants, the ≥ 2× throughput
/// acceptance bar enforced inside `run_serve_bench`.  Nightly tier
/// (`cargo test -- --ignored`) — too heavy for the per-commit suite.
#[test]
#[ignore = "nightly soak; run with --ignored"]
fn full_soak_meets_the_acceptance_bar() {
    let r = run_serve_bench(&ServeBenchConfig::new(false)).expect("full soak >= 2x");
    assert!(r.batch_speedup >= 2.0, "{:.2}x", r.batch_speedup);
}
