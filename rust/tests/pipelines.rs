//! Backend API v2 acceptance tests: capability-driven plugins that own
//! their compile pipeline.
//!
//! Pins the tentpole contracts:
//! * per-device pass lists (host-CPU backends append `plan-memory`, the
//!   Aurora inserts `ve-vectorize`) produce distinct
//!   `PipelineConfig` fingerprints;
//! * the `CompileCache` never serves an artifact compiled under another
//!   device's (or another registry's) pipeline;
//! * ablation toggles still address passes by name in custom pipelines —
//!   backend-defined passes included;
//! * the flavor-selection collapse kept shipped-backend flavors (and the
//!   fingerprint canonicalization) stable.

use std::sync::Arc;

use sol::backends::{aurora, default_registry, BackendRegistry, Capabilities, DeviceBackend};
use sol::devsim::DeviceId;
use sol::dfp::Flavor;
use sol::dnn::Library;
use sol::framework::DeviceType;
use sol::ir::Layout;
use sol::session::{
    stages, CacheKey, CompileCache, PassManager, Pipeline, PipelineBuilder, PipelineConfig,
    Session,
};
use sol::workloads::NetId;

// ---------------------------------------------------------------------
// pipeline divergence
// ---------------------------------------------------------------------

#[test]
fn aurora_pipeline_differs_from_x86_by_at_least_one_pass() {
    let r = default_registry();
    let x86 = r.pipeline_names_for(DeviceId::Xeon6126);
    let ve = r.pipeline_names_for(DeviceId::AuroraVE10B);
    assert_ne!(x86, ve);
    // by *which* passes: the planner is host-CPU-only, the vector audit
    // is Aurora-only
    assert!(x86.contains(&stages::PLAN_MEMORY));
    assert!(!ve.contains(&stages::PLAN_MEMORY));
    assert!(ve.contains(&aurora::VE_VECTORIZE));
    assert!(!x86.contains(&aurora::VE_VECTORIZE));
    // GPUs run the bare core stages
    assert_eq!(r.pipeline_names_for(DeviceId::TitanV), stages::CORE.to_vec());
    assert_eq!(r.pipeline_names_for(DeviceId::QuadroP4000), stages::CORE.to_vec());
}

#[test]
fn per_device_pipelines_have_distinct_fingerprints() {
    let s = Session::new();
    let cpu = s.pipeline_config(DeviceId::Xeon6126).fingerprint();
    let ve = s.pipeline_config(DeviceId::AuroraVE10B).fingerprint();
    let gpu = s.pipeline_config(DeviceId::TitanV).fingerprint();
    assert_ne!(cpu, ve);
    assert_ne!(cpu, gpu);
    assert_ne!(ve, gpu);
    // the pass list alone separates configs: same device, same flavor,
    // same layout — only the pipeline differs
    let mut a = PipelineConfig::new(DeviceId::Xeon6126);
    let mut b = a.clone();
    a.set_pipeline(default_registry().pipeline_names_for(DeviceId::Xeon6126));
    b.set_pipeline(stages::CORE.to_vec());
    assert_ne!(a.fingerprint(), b.fingerprint(), "pass list must be keyed");
}

#[test]
fn plan_memory_runs_exactly_where_the_backend_put_it() {
    let s = Session::new();
    let g = NetId::Squeezenet1_1.build(1);
    // host CPU: the backend appended plan-memory; the pass itself has no
    // device check, so the plan comes from pipeline membership alone
    let cpu = s.compile(&g, DeviceId::Xeon6126);
    assert!(cpu.memory_plan.is_some());
    let names: Vec<&str> = cpu.pass_records.iter().map(|r| r.name.as_str()).collect();
    assert_eq!(*names.last().unwrap(), stages::PLAN_MEMORY);
    // Aurora: no plan-memory record at all (not a skipped record — the
    // pass simply is not in the pipeline), but the ve audit ran
    let ve = s.compile(&g, DeviceId::AuroraVE10B);
    assert!(ve.memory_plan.is_none());
    let ve_names: Vec<&str> = ve.pass_records.iter().map(|r| r.name.as_str()).collect();
    assert!(!ve_names.contains(&stages::PLAN_MEMORY));
    let audit = ve
        .pass_records
        .iter()
        .find(|r| r.name == aurora::VE_VECTORIZE)
        .expect("ve audit in records");
    assert!(!audit.skipped);
}

// ---------------------------------------------------------------------
// cache isolation across pipelines
// ---------------------------------------------------------------------

/// A second backend driving the Xeon under a *different* pipeline (no
/// memory planner) — used to prove same-device/different-pipeline keys
/// never alias.
struct LeanXeon;

impl DeviceBackend for LeanXeon {
    fn name(&self) -> &'static str {
        "lean-xeon"
    }
    fn device(&self) -> DeviceId {
        DeviceId::Xeon6126
    }
    fn flavor(&self) -> Flavor {
        Flavor::Ispc
    }
    fn libraries(&self) -> Vec<Library> {
        vec![Library::OpenBlas]
    }
    fn framework_slot(&self) -> DeviceType {
        DeviceType::Cpu
    }
    fn pipeline(&self, base: &PipelineBuilder) -> Pipeline {
        base.core() // no plan-memory: bare paper stages
    }
}

#[test]
fn cache_never_serves_an_artifact_from_another_pipeline() {
    // one shared cache, two registries driving the *same device* under
    // different pipelines: the realized pass list is part of the
    // fingerprint, so the second compile must miss, not alias
    let g = NetId::Mlp.build(1);
    let cache = CompileCache::new();

    let full = Session::new().pipeline_config(DeviceId::Xeon6126);
    let mut lean_registry = BackendRegistry::new();
    lean_registry.register(Box::new(LeanXeon));
    let lean = Session::with_registry(lean_registry).pipeline_config(DeviceId::Xeon6126);
    assert_ne!(full.fingerprint(), lean.fingerprint());

    let k_full = CacheKey::of(&g, DeviceId::Xeon6126, full.fingerprint());
    let k_lean = CacheKey::of(&g, DeviceId::Xeon6126, lean.fingerprint());
    assert_ne!(k_full, k_lean);
    let a = cache.get_or_compile(k_full, || {
        PassManager::standard(full.clone()).compile(&g).unwrap()
    });
    let b = cache.get_or_compile(k_lean, || {
        LeanXeon.pipeline(&PipelineBuilder::new()).manager(lean.clone()).compile(&g).unwrap()
    });
    assert_eq!(cache.misses(), 2, "different pipelines must both miss");
    assert_eq!(cache.hits(), 0);
    assert!(!Arc::ptr_eq(&a, &b));
}

#[test]
fn cross_device_compiles_never_share_cache_entries() {
    let s = Session::new();
    let g = NetId::Resnet18.build(1);
    let cpu = s.compile_traced(&g, DeviceId::Xeon6126);
    let ve = s.compile_traced(&g, DeviceId::AuroraVE10B);
    assert_ne!(cpu.key, ve.key);
    assert!(!cpu.cache_hit && !ve.cache_hit);
    assert_eq!(s.cache().misses(), 2);
    // the artifacts really came from different pipelines
    assert!(cpu.model.memory_plan.is_some());
    assert!(ve.model.memory_plan.is_none());
}

// ---------------------------------------------------------------------
// ablation by name in custom / backend-extended pipelines
// ---------------------------------------------------------------------

#[test]
fn backend_defined_pass_toggles_by_name() {
    let s = Session::new();
    let g = NetId::Squeezenet1_1.build(1);
    let mut cfg = s.pipeline_config(DeviceId::AuroraVE10B);
    cfg.disable_pass(aurora::VE_VECTORIZE);
    let m = s.compile_with(&g, cfg).unwrap();
    let audit = m.pass_records.iter().find(|r| r.name == aurora::VE_VECTORIZE).unwrap();
    assert!(audit.skipped, "backend pass must be ablatable by name");
    // and the ablation is its own content address
    let base = s.compile_traced(&g, DeviceId::AuroraVE10B);
    assert!(!base.cache_hit, "ablated compile must not have polluted the default key");
}

#[test]
fn custom_pipeline_ablation_addresses_passes_by_name() {
    // a hand-built pipeline (core stages + plan-memory up front after
    // schedule) still honors name toggles once the config pins its list
    let b = PipelineBuilder::new();
    let pipeline = b.core().append(b.standard(stages::PLAN_MEMORY));
    let mut cfg = PipelineConfig::new(DeviceId::Xeon6126);
    cfg.set_pipeline(pipeline.names());
    cfg.disable_pass(stages::PLAN_MEMORY);
    let m = pipeline.manager(cfg).compile(&NetId::Mlp.build(1)).unwrap();
    let rec = m.pass_records.iter().find(|r| r.name == stages::PLAN_MEMORY).unwrap();
    assert!(rec.skipped);
    assert!(m.memory_plan.is_none());
}

#[test]
fn session_rejects_a_foreign_pinned_pipeline() {
    // a config pinned to a pass list that is not the registry's must be
    // an error, not a silent overwrite (the key would say one pipeline
    // while the session ran another)
    let s = Session::new();
    let mut cfg = s.pipeline_config(DeviceId::Xeon6126);
    cfg.set_pipeline(stages::CORE.to_vec()); // drops plan-memory: foreign
    let err = s.compile_with(&NetId::Mlp.build(1), cfg).unwrap_err();
    assert!(err.to_string().contains("pins pass list"), "{err}");
    assert_eq!(s.cache().len(), 0, "nothing may be cached under a mismatched key");
}

#[test]
#[should_panic(expected = "unknown pass")]
fn pass_missing_from_this_pipeline_fails_loudly() {
    // plan-memory exists as a standard pass, but the TitanV pipeline does
    // not run it — toggling it there is a bug, not a silent no-op
    let mut cfg = PipelineConfig::new(DeviceId::TitanV);
    cfg.disable_pass(stages::PLAN_MEMORY);
}

// ---------------------------------------------------------------------
// flavor-collapse / fingerprint regressions
// ---------------------------------------------------------------------

#[test]
fn explicit_backend_defaults_hash_like_the_implicit_ones() {
    // fingerprints canonicalize: an explicit flavor/layout equal to the
    // backend's default must produce the same key as leaving them unset —
    // the regression guard for the flavor-selection collapse (shipped
    // cache keys depend only on what actually compiles)
    for dev in DeviceId::ALL {
        let implicit = PipelineConfig::new(dev);
        let mut explicit = PipelineConfig::new(dev);
        explicit.flavor = Some(implicit.resolved_flavor());
        explicit.preferred_layout = Some(implicit.resolved_layout());
        explicit.set_pipeline(implicit.realized_passes());
        assert_eq!(implicit.fingerprint(), explicit.fingerprint(), "{dev:?}");
    }
}

#[test]
fn session_and_raw_config_agree_on_shipped_keys() {
    // Session::compile's precomputed per-device fingerprint equals the
    // raw PipelineConfig fingerprint for every shipped device (both
    // resolve through the same default registry)
    let s = Session::new();
    let g = NetId::Mlp.build(1);
    for dev in DeviceId::ALL {
        let out = s.compile_traced(&g, dev);
        let want = CacheKey::of(&g, dev, PipelineConfig::new(dev).fingerprint());
        assert_eq!(out.key, want, "{dev:?}");
    }
}

#[test]
fn capability_sheet_reaches_the_compiled_layout() {
    // preferred_layout is routed from the backend capability sheet into
    // the assign-layouts pass: the x86 backend's BlockedC16 shows up in
    // the compiled plan, a CUDA device's Nchw produces zero reorders
    let s = Session::new();
    let g = NetId::Vgg16.build(1);
    let cpu = s.compile(&g, DeviceId::Xeon6126);
    assert!(cpu.layout.per_node.contains(&Layout::BlockedC16));
    let gpu = s.compile(&g, DeviceId::TitanV);
    assert!(gpu.layout.reorders.is_empty());
    // and the registry surfaces the same sheets
    let caps = default_registry().capabilities_for(DeviceId::Xeon6126);
    assert_eq!(caps.preferred_layout, Layout::BlockedC16);
    assert!(caps.arena_exec && !caps.offload);
    assert_eq!(
        default_registry().capabilities_for(DeviceId::AuroraVE10B),
        Capabilities {
            offload: true,
            arena_exec: false,
            preferred_layout: Layout::Nchw,
            vector_width: 256,
        }
    );
}

#[test]
fn custom_layout_capability_changes_artifact_and_key() {
    // a backend that prefers NHWC on the Xeon: the layout pass must
    // follow the capability sheet and the cache key must diverge
    struct NhwcXeon;
    impl DeviceBackend for NhwcXeon {
        fn name(&self) -> &'static str {
            "nhwc-xeon"
        }
        fn device(&self) -> DeviceId {
            DeviceId::Xeon6126
        }
        fn flavor(&self) -> Flavor {
            Flavor::Ispc
        }
        fn libraries(&self) -> Vec<Library> {
            Vec::new()
        }
        fn framework_slot(&self) -> DeviceType {
            DeviceType::Cpu
        }
        fn capabilities(&self) -> Capabilities {
            Capabilities {
                preferred_layout: Layout::Nhwc,
                ..Capabilities::for_device(DeviceId::Xeon6126)
            }
        }
    }
    let mut r = BackendRegistry::new();
    r.register(Box::new(NhwcXeon));
    let s = Session::with_registry(r);
    let g = NetId::Vgg16.build(1);
    let out = s.compile_traced(&g, DeviceId::Xeon6126);
    assert!(out.model.layout.per_node.contains(&Layout::Nhwc));
    assert!(!out.model.layout.per_node.contains(&Layout::BlockedC16));
    let default = Session::new().compile_traced(&g, DeviceId::Xeon6126);
    assert_ne!(out.key, default.key, "capability layout must be keyed");
}
