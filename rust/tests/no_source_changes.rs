//! THE paper's central claim, enforced mechanically: device support was
//! added **without changing the framework's source code**.
//!
//! `rust/src/framework/` is the stand-in for PyTorch.  Its sources must
//! not reference the middleware in any way: no `SOL` strings, no imports
//! of middleware modules, no middleware type names.  The only coupling
//! allowed is the framework's own *public* extension API (operator
//! registry, allocator, hooks), used from `frontend/` — one-directionally.

use std::fs;
use std::path::{Path, PathBuf};

fn framework_sources() -> Vec<(PathBuf, String)> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src/framework");
    let mut out = Vec::new();
    fn walk(p: &Path, out: &mut Vec<(PathBuf, String)>) {
        for e in fs::read_dir(p).unwrap().flatten() {
            let path = e.path();
            if path.is_dir() {
                walk(&path, out);
            } else if path.extension().is_some_and(|x| x == "rs") {
                let src = fs::read_to_string(&path).unwrap();
                out.push((path, src));
            }
        }
    }
    walk(&dir, &mut out);
    assert!(out.len() >= 8, "framework sources missing?");
    out
}

#[test]
fn framework_never_names_the_middleware() {
    for (path, src) in framework_sources() {
        assert!(
            !src.contains("SOL"),
            "{path:?} references the middleware by name"
        );
        // `sol::` would be a crate-path import of the middleware from
        // within the framework — the exact thing the paper avoids.
        assert!(!src.contains("sol::"), "{path:?} imports middleware paths");
    }
}

#[test]
fn framework_never_imports_middleware_modules() {
    const FORBIDDEN: &[&str] = &[
        "crate::frontend",
        "crate::passes",
        "crate::dfp",
        "crate::dnn",
        "crate::runtime",
        "crate::devsim",
        "crate::backends",
        "crate::deploy",
        "crate::ir",
        "crate::workloads",
        "crate::exec",
    ];
    for (path, src) in framework_sources() {
        for f in FORBIDDEN {
            assert!(!src.contains(f), "{path:?} references {f}");
        }
    }
}

#[test]
fn framework_never_names_middleware_types() {
    // type names that only exist middleware-side
    const TYPES: &[&str] = &[
        "SolModel",
        "OptimizedModel",
        "KernelPlan",
        "DnnPlan",
        "TransparentOffload",
        "DeviceSpec",
        "PjrtEngine",
        "AsyncQueue",
        "VirtualPtr",
    ];
    for (path, src) in framework_sources() {
        for t in TYPES {
            assert!(!src.contains(t), "{path:?} references middleware type {t}");
        }
    }
}

#[test]
fn integration_goes_through_public_extension_points_only() {
    // the frontend may ONLY touch the framework through these public APIs
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src/frontend");
    let native = fs::read_to_string(dir.join("native.rs")).unwrap();
    // it uses the public registration functions...
    assert!(native.contains("set_allocator"));
    assert!(native.contains("set_hooks"));
    assert!(native.contains("register_stub"));
    // ...and never constructs framework-internal state directly
    assert!(!native.contains("Storage::"), "bypasses the tensor API");
}
