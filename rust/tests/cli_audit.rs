//! Golden-file test for `sol audit --json`: the machine-readable audit
//! report is the CI divergence gate's interface, so its shape (keys,
//! device list, variant grid, tolerance policies, deterministic
//! counts) must change deliberately.  Golden comparison is over *parsed*
//! JSON, not raw text — formatting is free to evolve, values are not.
//!
//! To bless a new golden after an intentional change:
//! `BLESS=1 cargo test --test cli_audit`.

use std::path::PathBuf;
use std::process::Command;

use sol::util::Json;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/sol_audit.json")
}

fn run_audit(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_sol"))
        .arg("audit")
        .args(args)
        .output()
        .expect("run sol audit")
}

#[test]
fn sol_audit_json_matches_golden() {
    let out = run_audit(&["--seeds", "2", "--json"]);
    assert!(out.status.success(), "sol audit failed: {out:?}");
    let stdout = String::from_utf8(out.stdout).expect("utf8 stdout");
    if std::env::var_os("BLESS").is_some() {
        std::fs::write(golden_path(), &stdout).expect("bless golden");
        return;
    }
    let got = Json::parse(&stdout).expect("audit stdout parses as JSON");
    let want = Json::parse(&std::fs::read_to_string(golden_path()).expect("read golden"))
        .expect("golden parses as JSON");
    assert_eq!(
        got, want,
        "`sol audit --seeds 2 --json` drifted from the golden report \
         (rust/tests/golden/sol_audit.json) — re-bless with BLESS=1 if intentional"
    );
}

#[test]
fn sol_audit_json_has_the_gate_contract_shape() {
    // structural sanity independent of the golden values
    let out = run_audit(&["--seeds", "1", "--json"]);
    assert!(out.status.success(), "clean sweep must exit 0");
    let doc = Json::parse(&String::from_utf8(out.stdout).unwrap()).unwrap();
    let keys = [
        "audit", "seeds", "devices", "workloads", "grid", "policies", "variants", "skipped",
        "comparisons", "findings", "status",
    ];
    for key in keys {
        assert!(doc.get(key).is_some(), "missing report key '{key}'");
    }
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("pass"));
    assert_eq!(doc.get("seeds").and_then(Json::as_f64), Some(1.0));
    let devices = doc.get("devices").and_then(Json::as_arr).unwrap();
    let grid = doc.get("grid").and_then(Json::as_arr).unwrap();
    assert!(grid.len() >= devices.len(), "every device runs at least its naive slot");
    // 3 fixed workloads + 1 seeded
    assert_eq!(doc.get("workloads").and_then(Json::as_arr).unwrap().len(), 4);
    assert!(doc.get("findings").and_then(Json::as_arr).unwrap().is_empty());
}

#[test]
fn sol_audit_fault_injection_trips_the_gate_with_exit_code_2() {
    let out = run_audit(&["--seeds", "0", "--json", "--fault", "titanv:offload:0.5"]);
    assert_eq!(out.status.code(), Some(2), "findings must exit 2 (the CI gate): {out:?}");
    let doc = Json::parse(&String::from_utf8(out.stdout).unwrap()).unwrap();
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("fail"));
    let findings = doc.get("findings").and_then(Json::as_arr).unwrap();
    assert!(!findings.is_empty());
    // findings carry the reproduction handle: the device pair with both
    // pipeline fingerprints, the policy, and the worst-element drift
    let f = &findings[0];
    for key in ["workload", "left", "right", "op_class", "policy", "worst_index", "max_abs"] {
        assert!(f.get(key).is_some(), "finding missing '{key}'");
    }
    let sides = [f.get("left").unwrap(), f.get("right").unwrap()];
    assert!(sides.iter().any(|s| {
        s.get("device").and_then(Json::as_str) == Some("TitanV")
            && s.get("path").and_then(Json::as_str) == Some("offload")
    }));
    for s in sides {
        let fp = s.get("fingerprint").and_then(Json::as_str).unwrap();
        assert_eq!(fp.len(), 16, "fingerprints render as 16 hex digits");
        if s.get("device").and_then(Json::as_str).is_some() {
            assert_ne!(fp, "0000000000000000", "device variants carry real fingerprints");
        }
    }
}
