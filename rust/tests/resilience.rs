//! Acceptance tests for the spine's fault-tolerance layer — the
//! circuit-breaker health machine, failover placement, the
//! batch-bisection degradation ladder, and panic containment — all
//! driven in manual-pump mode (`workers: 0`) on the spine's virtual
//! clock, so every assertion is deterministic (no sleeps, no timing
//! flakes).
//!
//! Fault injection goes through the spine's own
//! [`sol::util::fault::FaultInjector`] (the same instrument `sol chaos`
//! and `sol audit --fault` use), never through ad-hoc test doubles: the
//! tests exercise exactly the failure paths production would take.

use std::sync::Arc;

use sol::audit::fixed_workloads;
use sol::backends::{BackendRegistry, Capabilities, DeviceBackend};
use sol::devsim::DeviceId;
use sol::dfp::Flavor;
use sol::dnn::Library;
use sol::framework::DeviceType;
use sol::frontend::extract_graph;
use sol::session::{
    AdmissionError, DeviceHealth, DrainOutcome, RequestHandle, ServedArtifact, ServingConfig,
    ServingSession, Session, SpineConfig, Tenant,
};
use sol::util::fault::{FaultAction, FaultRule, FaultSite};

const XEON: DeviceId = DeviceId::Xeon6126;
const TITAN: DeviceId = DeviceId::TitanV;

fn assert_close(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + a.abs().max(b.abs())),
            "{ctx}: elem {i}: {a} vs {b}"
        );
    }
}

/// Manual-pump spine with the resilience knobs at test-friendly values.
fn resilient(trip_after: u32) -> SpineConfig {
    SpineConfig {
        workers: 0,
        queue_depth: 64,
        max_batch: 4,
        trip_after,
        probe_backoff_us: 1_000,
        probe_backoff_max_us: 8_000,
        ..SpineConfig::default()
    }
}

/// Single-device serving over the default registry.
fn pump_spine(cfg: SpineConfig) -> ServingSession {
    assert_eq!(cfg.workers, 0, "resilience tests must stay deterministic");
    let serving = ServingSession::new(ServingConfig::default());
    serving.spine_with(cfg);
    serving
}

/// A host-executing backend on the Xeon (default capabilities already
/// include the arena path).
struct XeonHost;

impl DeviceBackend for XeonHost {
    fn name(&self) -> &'static str {
        "xeon-host"
    }
    fn device(&self) -> DeviceId {
        XEON
    }
    fn flavor(&self) -> Flavor {
        Flavor::Ispc
    }
    fn libraries(&self) -> Vec<Library> {
        vec![Library::OpenBlas]
    }
    fn framework_slot(&self) -> DeviceType {
        DeviceType::Cpu
    }
}

/// A host-executing backend on a second device: the same structural
/// graph compiles into a sibling artifact the breaker can fail over to.
struct TitanHost;

impl DeviceBackend for TitanHost {
    fn name(&self) -> &'static str {
        "titan-host"
    }
    fn device(&self) -> DeviceId {
        TITAN
    }
    fn flavor(&self) -> Flavor {
        Flavor::Ispc
    }
    fn libraries(&self) -> Vec<Library> {
        vec![Library::OpenBlas]
    }
    fn framework_slot(&self) -> DeviceType {
        DeviceType::Cuda
    }
    fn capabilities(&self) -> Capabilities {
        Capabilities { arena_exec: true, ..Capabilities::for_device(TITAN) }
    }
}

fn two_device_serving(cfg: SpineConfig) -> ServingSession {
    assert_eq!(cfg.workers, 0, "resilience tests must stay deterministic");
    let mut reg = BackendRegistry::new();
    reg.register(Box::new(XeonHost));
    reg.register(Box::new(TitanHost));
    let serving = ServingSession::over(Session::with_registry(reg), ServingConfig::default());
    serving.spine_with(cfg);
    serving
}

/// Load the mlp workload on `devices`, returning the tenant + artifacts.
fn mlp_artifacts(
    serving: &ServingSession,
    devices: &[DeviceId],
) -> (Tenant, Vec<Arc<ServedArtifact>>) {
    let wl = &fixed_workloads()[2]; // mlp
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mlp").unwrap();
    let t = serving.tenant("resilience");
    let arts = devices.iter().map(|&d| t.load_artifact(&g, &b, d).unwrap()).collect();
    (t, arts)
}

fn submit_n(t: &Tenant, art: &Arc<ServedArtifact>, n: usize, fill: f32) -> Vec<RequestHandle> {
    (0..n).map(|_| t.submit(art, vec![fill; art.input_len()], None).unwrap()).collect()
}

fn health_of(serving: &ServingSession, d: DeviceId) -> (DeviceHealth, u64, u64) {
    serving
        .spine()
        .device_health()
        .into_iter()
        .find(|(dev, _, _, _)| *dev == d)
        .map(|(_, h, t, p)| (h, t, p))
        .expect("device has a breaker row")
}

// ---------------------------------------------------------------------
// panic containment + poison-recovering locks
// ---------------------------------------------------------------------

/// An injected panic inside a batch execution is contained
/// (`catch_unwind`): every request still resolves, the spine's locks
/// recover (a later wave drains normally instead of hitting a poisoned
/// mutex), and the health section shows up in the serving report.
#[test]
fn injected_panic_is_contained_and_spine_stays_usable() {
    let serving = pump_spine(resilient(3));
    let (t, arts) = mlp_artifacts(&serving, &[XEON]);
    let spine = serving.spine();
    spine.fault_injector().push_rule(FaultRule {
        device: None,
        site: Some(FaultSite::Batch),
        action: FaultAction::Panic,
        rate: 1.0,
        remaining: Some(1),
    });

    let handles = submit_n(&t, &arts[0], 4, 0.2);
    spine.advance_clock_us(300);
    assert_eq!(spine.drain_device(XEON), 4);
    for h in handles {
        h.wait().expect("the ladder rescues every request past a contained panic");
    }
    assert!(spine.stats().retries > 0, "the panic forced the ladder to retry");

    // the spine survived the panic: a clean wave drains as usual
    let handles = submit_n(&t, &arts[0], 4, 0.3);
    spine.advance_clock_us(300);
    assert_eq!(spine.drain_device(XEON), 4);
    for h in handles {
        h.wait().unwrap();
    }
    assert_eq!(health_of(&serving, XEON).0, DeviceHealth::Healthy);

    let report = serving.serving_report();
    assert!(report.contains("health:"), "report shows the health section:\n{report}");
    assert!(report.contains("resilience:"), "report shows the resilience line:\n{report}");
}

// ---------------------------------------------------------------------
// batch bisection
// ---------------------------------------------------------------------

/// One poison request in a batch of four is bisected out: exactly that
/// request fails, its three batchmates are served (with correct
/// outputs), and the device stays healthy — one bad request must never
/// quarantine a device.
#[test]
fn poison_requests_are_bisected_out() {
    const POISON: f32 = 1e30;
    let serving = pump_spine(resilient(3));
    let (t, arts) = mlp_artifacts(&serving, &[XEON]);
    let spine = serving.spine();
    spine.fault_injector().set_poison(Some(POISON));

    let mut handles = Vec::new();
    for i in 0..4 {
        let mut x = vec![0.2 + 0.1 * i as f32; arts[0].input_len()];
        if i == 2 {
            x[0] = POISON;
        }
        handles.push((t.submit(&arts[0], x.clone(), None).unwrap(), x));
    }
    spine.advance_clock_us(300);
    assert_eq!(spine.drain_device(XEON), 4);

    for (i, (h, x)) in handles.into_iter().enumerate() {
        if i == 2 {
            let err = h.wait().unwrap_err();
            assert!(
                matches!(err, AdmissionError::Failed { .. }),
                "poison resolves Failed, got {err:?}"
            );
        } else {
            let out = h.wait().expect("innocent batchmates are served");
            let mut want = Vec::new();
            arts[0].run_blocking(&x, &mut want).unwrap();
            assert_close(&out.output, &want, &format!("request {i}"));
        }
    }
    let st = spine.stats();
    assert_eq!(st.poison, 1, "exactly the sentinel request is poison");
    assert!(st.retries > 0, "bisection consumed retries");
    assert_eq!(health_of(&serving, XEON).0, DeviceHealth::Healthy);
    assert_eq!(health_of(&serving, XEON).1, 0, "no trip for one poison request");
}

/// A fault that only hits the *batched* path degrades to the naive
/// per-request fallback: every request is still served, with outputs
/// matching a direct single-request execution, and the breaker hears
/// success (no quarantine) because requests were ultimately served.
#[test]
fn batch_faults_fall_back_to_naive_execution() {
    let serving = pump_spine(resilient(3));
    let (t, arts) = mlp_artifacts(&serving, &[XEON]);
    let spine = serving.spine();
    spine.fault_injector().push_rule(FaultRule {
        device: None,
        site: Some(FaultSite::Batch),
        action: FaultAction::Fail,
        rate: 1.0,
        remaining: None, // every arena execution fails, forever
    });

    let handles = submit_n(&t, &arts[0], 4, 0.4);
    spine.advance_clock_us(300);
    assert_eq!(spine.drain_device(XEON), 4);
    let x = vec![0.4f32; arts[0].input_len()];
    let mut want = Vec::new();
    arts[0].run_blocking(&x, &mut want).unwrap();
    for (i, h) in handles.into_iter().enumerate() {
        let out = h.wait().expect("naive rescue serves every request");
        assert_eq!(out.batch_size, 1, "rescues run per-request");
        assert_close(&out.output, &want, &format!("request {i}"));
    }
    let st = spine.stats();
    assert!(st.retries >= 4, "each request walked the ladder");
    assert_eq!(st.poison, 0);
    let (health, trips, _) = health_of(&serving, XEON);
    assert_eq!((health, trips), (DeviceHealth::Healthy, 0), "served requests keep it closed");
}

/// With *every* path failing (batch and naive), the ladder is bounded:
/// each request resolves `Failed` after exhausting its retry budget —
/// no infinite retry loops, no lost waiters.
#[test]
fn retry_budget_bounds_the_ladder() {
    let serving = pump_spine(resilient(3));
    let (t, arts) = mlp_artifacts(&serving, &[XEON]);
    let spine = serving.spine();
    spine.fault_injector().push_rule(FaultRule {
        device: None,
        site: None, // batch *and* naive: nothing can serve this device
        action: FaultAction::Fail,
        rate: 1.0,
        remaining: None,
    });

    let handles = submit_n(&t, &arts[0], 4, 0.5);
    spine.advance_clock_us(300);
    assert_eq!(spine.drain_device(XEON), 4);
    for h in handles {
        let err = h.wait().unwrap_err();
        assert!(matches!(err, AdmissionError::Failed { .. }), "bounded failure, got {err:?}");
    }
    let st = spine.stats();
    let max_retries = SpineConfig::default().max_retries as u64;
    assert!(st.retries > 0 && st.retries <= 4 * max_retries, "ladder bounded: {}", st.retries);
    assert_eq!(st.poison, 4, "every request exhausted its last rung");
    assert_eq!(st.queued, 0, "no waiter left behind");
}

// ---------------------------------------------------------------------
// circuit breaker: trip, failover placement, recovery
// ---------------------------------------------------------------------

/// Consecutive dead batches trip the device's breaker; new submits fail
/// over to the healthy same-family sibling; once the fault clears and
/// the backoff elapses, a half-open probe restores the device.
#[test]
fn tripped_device_fails_over_and_recovers() {
    let serving = two_device_serving(resilient(2));
    let (t, arts) = mlp_artifacts(&serving, &[XEON, TITAN]);
    let spine = serving.spine();
    spine.fault_injector().push_rule(FaultRule {
        device: Some(XEON),
        site: None, // the whole device is dead: naive can't rescue either
        action: FaultAction::Fail,
        rate: 1.0,
        remaining: None,
    });

    // two consecutive dead batches → quarantine
    for wave in 0..2 {
        let handles = submit_n(&t, &arts[0], 4, 0.2);
        spine.advance_clock_us(300);
        assert_eq!(spine.drain_one(XEON), 4, "wave {wave} resolves");
        for h in handles {
            h.wait().unwrap_err();
        }
    }
    let (health, trips, _) = health_of(&serving, XEON);
    assert_eq!((health, trips), (DeviceHealth::Quarantined, 1));

    // submits against the tripped device re-route to the sibling
    let failover_before = spine.stats().failover;
    let handles = submit_n(&t, &arts[0], 4, 0.3);
    assert!(spine.stats().failover >= failover_before + 4, "placement failed over");
    spine.advance_clock_us(300);
    assert_eq!(spine.drain_one(TITAN), 4);
    for h in handles {
        let out = h.wait().expect("failed-over requests are served");
        assert_eq!(out.device, TITAN);
    }

    // fault clears, backoff elapses → a half-open probe heals the device
    spine.fault_injector().clear_rules_for(XEON);
    spine.advance_clock_us(1_500); // past probe_backoff_us
    let handles = submit_n(&t, &arts[0], 1, 0.4);
    spine.advance_clock_us(300);
    assert_eq!(spine.drain_one(XEON), 1, "the probe batch runs (capped at 1)");
    let out = handles.into_iter().next().unwrap().wait().expect("probe succeeds");
    assert_eq!(out.device, XEON);
    let (health, trips, probes) = health_of(&serving, XEON);
    assert_eq!((health, trips, probes), (DeviceHealth::Healthy, 1, 1));

    // and normal service resumes on the healed device
    let handles = submit_n(&t, &arts[0], 4, 0.5);
    spine.advance_clock_us(300);
    assert_eq!(spine.drain_device(XEON), 4);
    for h in handles {
        assert_eq!(h.wait().unwrap().device, XEON);
    }
}

/// Requests already *queued* on a device when it trips are not stranded:
/// the next (non-forced) drain migrates them to the healthy sibling's
/// queue and drains them there inline.
#[test]
fn queued_requests_migrate_off_a_tripped_device() {
    let serving = two_device_serving(resilient(1));
    let (t, arts) = mlp_artifacts(&serving, &[XEON, TITAN]);
    let spine = serving.spine();
    spine.fault_injector().push_rule(FaultRule {
        device: Some(XEON),
        site: None,
        action: FaultAction::Fail,
        rate: 1.0,
        remaining: None,
    });

    // 8 queued; the first batch of 4 dies and trips the breaker
    // (trip_after: 1), leaving 4 stranded on the quarantined queue
    let handles = submit_n(&t, &arts[0], 8, 0.2);
    spine.advance_clock_us(300);
    assert_eq!(spine.drain_one(XEON), 4);
    assert_eq!(health_of(&serving, XEON).0, DeviceHealth::Quarantined);
    assert_eq!(spine.stats().queued, 4);

    // the next pump migrates the stranded 4 to the Titan and serves them
    match spine.pump(XEON) {
        DrainOutcome::Completed(4) => {}
        other => panic!("migration drain: want Completed(4), got {other:?}"),
    }
    for (i, h) in handles.into_iter().enumerate() {
        if i < 4 {
            h.wait().unwrap_err();
        } else {
            let out = h.wait().expect("migrated requests are served");
            assert_eq!(out.device, TITAN, "request {i} ran on the sibling");
        }
    }
    let st = spine.stats();
    assert!(st.failover >= 4, "migration counts as failover");
    assert_eq!(st.queued, 0, "nothing left stranded");
}
