//! E9 — every qualitative observation of the paper's §VI evaluation,
//! asserted end-to-end against the reproduction (DESIGN.md experiment
//! index).

use sol::devsim::{DeviceId, EfficiencyTable, SimEngine};
use sol::exec::baseline::{baseline_infer_steps, baseline_train_steps, BaselineKind};
use sol::exec::fig3::{fig3_grid, fig3_row, headline_speedups};
use sol::exec::solrun::{sol_infer_steps, sol_train_steps, OffloadMode};
use sol::passes::{optimize, OptimizeOptions};
use sol::workloads::NetId;

fn eff() -> EfficiencyTable {
    EfficiencyTable::default()
}

/// §VI-C: "Overall SOL is always faster than the baseline implementations
/// in the inference tests, on all devices."  (Full 13-net grid.)
#[test]
fn sol_always_wins_inference_full_grid() {
    for row in fig3_grid(false, &eff()) {
        if let Some(b) = row.baseline_ms {
            assert!(
                row.sol_ms <= b * 1.02,
                "{} on {:?}: sol {:.2} vs baseline {:.2}",
                row.net.name(),
                row.device,
                row.sol_ms,
                b
            );
        }
    }
}

/// §VI-C: "For the MLP there is no difference visible. MLPs do not provide
/// optimization capabilities to SOL as it mainly relies on matrix
/// multiplications."
#[test]
fn mlp_no_gain_on_cpu() {
    let r = fig3_row(NetId::Mlp, DeviceId::Xeon6126, false, &eff());
    let s = r.speedup().unwrap();
    assert!((0.8..1.25).contains(&s), "MLP CPU speedup {s:.2} should be ~1");
}

/// §VI-C: "TF-VE is always significantly slower than SOL ... only 1 out
/// of 8 SX-Aurora cores is active."
#[test]
fn tfve_always_significantly_slower_at_inference() {
    for net in NetId::ALL {
        if !net.supported_by_tfve() {
            continue;
        }
        let r = fig3_row(net, DeviceId::AuroraVE10B, false, &eff());
        assert!(
            r.speedup().unwrap() > 2.0,
            "{}: aurora speedup only {:.2}",
            net.name(),
            r.speedup().unwrap()
        );
    }
}

/// §VI-B: "ShuffleNet is not supported by TensorFlow-VE 2.1 as it does not
/// support 5D permutations."
#[test]
fn shufflenet_missing_from_tfve() {
    for net in [NetId::ShufflenetV2X0_5, NetId::ShufflenetV2X1_0] {
        let r = fig3_row(net, DeviceId::AuroraVE10B, false, &eff());
        assert!(r.baseline_ms.is_none());
        // but PyTorch runs it on other devices
        let c = fig3_row(net, DeviceId::Xeon6126, false, &eff());
        assert!(c.baseline_ms.is_some());
    }
}

/// §VI-C: "there is no difference to be seen between the transparent and
/// native offloading model [for inference], as the data needed to be
/// copied in inference is too small to make an actual difference."
#[test]
fn to_and_native_tie_at_inference() {
    for net in [NetId::Resnet50, NetId::Densenet121, NetId::Vgg16] {
        let r = fig3_row(net, DeviceId::AuroraVE10B, false, &eff());
        let rel = (r.sol_to_ms - r.sol_ms).abs() / r.sol_ms;
        assert!(rel < 0.10, "{}: TO {:.3} vs native {:.3}", net.name(), r.sol_to_ms, r.sol_ms);
    }
}

/// §VI-D: "the native offloading always yields in higher performance,
/// because of less memcopy between the host and the device" (training).
#[test]
fn native_beats_to_at_training_on_offload_devices() {
    for net in [NetId::Resnet50, NetId::Vgg16, NetId::Densenet121, NetId::Mlp] {
        for dev in [DeviceId::AuroraVE10B, DeviceId::TitanV] {
            let r = fig3_row(net, dev, true, &eff());
            assert!(
                r.sol_ms < r.sol_to_ms,
                "{} on {:?}: native {:.2} !< TO {:.2}",
                net.name(),
                dev,
                r.sol_ms,
                r.sol_to_ms
            );
        }
    }
}

/// §VI-D: "We identified that SOL's code generated for the grouped
/// convolutions is slower than the implementation within VEDNN" — the
/// MNasNet training exception where TF-VE is NOT slowest.
#[test]
fn mnasnet_grouped_conv_close_on_aurora_training() {
    // The speedup on MNasNet training must be the smallest among CNNs on
    // the Aurora (the paper's only training case where TF-VE wins).
    let mn = fig3_row(NetId::Mnasnet1_0, DeviceId::AuroraVE10B, true, &eff());
    let rn = fig3_row(NetId::Resnet50, DeviceId::AuroraVE10B, true, &eff());
    let dn = fig3_row(NetId::Densenet121, DeviceId::AuroraVE10B, true, &eff());
    let s_mn = mn.speedup().unwrap();
    assert!(s_mn < rn.speedup().unwrap());
    assert!(s_mn < dn.speedup().unwrap());
    assert!(s_mn < 1.6, "mnasnet aurora training speedup should be marginal: {s_mn:.2}");
}

/// §VI-D: "The GPU performance gain of SOL is not as high as for the
/// inference cases, but still never slower than PyTorch."
#[test]
fn gpu_training_small_but_nonnegative() {
    // dispatch-heavy nets, where the inference gain is largest; the
    // train<infer relation is cleanest on the high-end GPU (on the P4000
    // B=1 inference is already compute-bound, blunting its gain)
    for net in [NetId::Densenet169, NetId::Squeezenet1_0, NetId::ShufflenetV2X1_0] {
        for dev in [DeviceId::QuadroP4000, DeviceId::TitanV] {
            let tr = fig3_row(net, dev, true, &eff());
            let (st, _) = (tr.speedup().unwrap(), ());
            assert!(st >= 0.98, "{} {:?}: training slower than PyTorch", net.name(), dev);
        }
    }
    // the "not as high as inference" relation holds at the device level
    // (max over nets) — asserted in headline_shape; per-net it can invert
    // for DenseNet (SOL's B=1 inference is floor-limited by kernel count),
    // recorded as a deviation in EXPERIMENTS.md.
}

/// §I headline shape: Aurora shows the largest inference speedup; every
/// device's training max is below its inference max.
#[test]
fn headline_shape() {
    let inf = headline_speedups(&fig3_grid(false, &eff()));
    let tr = headline_speedups(&fig3_grid(true, &eff()));
    let get = |v: &[(DeviceId, f64)], d: DeviceId| v.iter().find(|(x, _)| *x == d).unwrap().1;
    let aurora_inf = get(&inf, DeviceId::AuroraVE10B);
    for (d, s) in &inf {
        if *d != DeviceId::AuroraVE10B {
            assert!(aurora_inf > *s, "aurora {aurora_inf:.1} vs {d:?} {s:.1}");
        }
    }
    for ((d, i), (_, t)) in inf.iter().zip(&tr) {
        assert!(t < i, "{d:?}");
    }
    // rough magnitudes: aurora in the double digits, like the paper's 25x
    assert!(aurora_inf > 8.0);
    assert!(get(&inf, DeviceId::Xeon6126) > 2.5); // paper: 7.79
}

/// §VI-D CPU training: "SOL is always faster, especially in Densenet where
/// the execution time is more than halved."
#[test]
fn densenet_cpu_training_halved() {
    // measured 1.87x on this substrate vs the paper's ">2x" — recorded as
    // a deviation in EXPERIMENTS.md; the assertion pins the regime.
    let r = fig3_row(NetId::Densenet121, DeviceId::Xeon6126, true, &eff());
    assert!(r.speedup().unwrap() >= 1.7, "{:?}", r.speedup());
    // and SOL is faster for every CNN on CPU training
    for net in NetId::ALL {
        let r = fig3_row(net, DeviceId::Xeon6126, true, &eff());
        assert!(r.speedup().unwrap() > 0.98, "{}", net.name());
    }
}

/// §IV-C design claims, directly on the schedules: the async queue hides
/// VEoffload launch latency, packing reduces wire ops.
#[test]
fn async_queue_and_packing_matter_on_aurora() {
    let g = NetId::Densenet121.build(1);
    let m = optimize(&g, &OptimizeOptions::new(DeviceId::AuroraVE10B));
    let steps = sol_infer_steps(&m, OffloadMode::Native, false);
    let e = eff();
    let sync = SimEngine::new(DeviceId::AuroraVE10B.spec(), e.clone(), false).run(&steps);
    let asyn = SimEngine::new(DeviceId::AuroraVE10B.spec(), e, true).run(&steps);
    assert!(
        asyn.total_us < sync.total_us * 0.75,
        "async {:.0}us vs sync {:.0}us",
        asyn.total_us,
        sync.total_us
    );
}

/// Training step scheduling sanity: training step > inference on the same
/// net/device for the baseline too.
#[test]
fn training_more_expensive_than_inference_everywhere() {
    let e = eff();
    for dev in DeviceId::ALL {
        let kind = BaselineKind::for_device(dev);
        let gi = NetId::Resnet18.build(1);
        let gt = NetId::Resnet18.build(16);
        let eng = SimEngine::new(dev.spec(), e.clone(), false);
        let inf = eng.run(&baseline_infer_steps(&gi, dev, kind, &e));
        let tr = eng.run(&baseline_train_steps(&gt, dev, kind, &e));
        assert!(tr.total_us > inf.total_us, "{dev:?}");
        // SOL side too
        let m = optimize(&gt, &OptimizeOptions::new(dev));
        let s_inf = eng.run(&sol_infer_steps(&m, OffloadMode::Native, false));
        let s_tr = eng.run(&sol_train_steps(&m, OffloadMode::Native));
        assert!(s_tr.total_us > s_inf.total_us, "{dev:?}");
    }
}
