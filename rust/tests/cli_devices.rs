//! Golden-file test for `sol devices`: the registered-backend plugin
//! listing (name, device, flavor, framework slot, capability sheet,
//! libraries, realized pipeline) is part of the backend API v2 surface —
//! adding/changing a backend must show up here deliberately.
//!
//! To bless a new golden after an intentional change:
//! `BLESS=1 cargo test --test cli_devices`.

use std::path::PathBuf;
use std::process::Command;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/sol_devices.txt")
}

/// The backend-listing section of `sol devices` stdout (from the
/// "registered backends" header to the end; the spec table above it is
/// pinned by `benches/specs.rs`).
fn backend_section(stdout: &str) -> String {
    let start = stdout
        .find("registered backends")
        .expect("`sol devices` must print the backend listing");
    stdout[start..].to_string()
}

#[test]
fn sol_devices_backend_listing_matches_golden() {
    let out = Command::new(env!("CARGO_BIN_EXE_sol"))
        .arg("devices")
        .output()
        .expect("run sol devices");
    assert!(out.status.success(), "sol devices failed: {:?}", out);
    let stdout = String::from_utf8(out.stdout).expect("utf8 stdout");
    let got = backend_section(&stdout);

    if std::env::var_os("BLESS").is_some() {
        std::fs::write(golden_path(), &got).expect("bless golden");
        return;
    }
    let want = std::fs::read_to_string(golden_path()).expect("read golden file");
    assert_eq!(
        got, want,
        "`sol devices` backend listing drifted from the golden file \
         (rust/tests/golden/sol_devices.txt) — re-bless with BLESS=1 if intentional"
    );
}

#[test]
fn sol_devices_json_reports_every_spec_and_backend() {
    use sol::devsim::DeviceId;
    use sol::util::Json;
    let out = Command::new(env!("CARGO_BIN_EXE_sol"))
        .args(["devices", "--json"])
        .output()
        .expect("run sol devices --json");
    assert!(out.status.success(), "sol devices --json failed: {out:?}");
    let doc = Json::parse(&String::from_utf8(out.stdout).unwrap())
        .expect("devices stdout parses as JSON");
    let devices = doc.get("devices").and_then(Json::as_arr).expect("devices array");
    assert_eq!(devices.len(), DeviceId::ALL.len(), "one entry per DeviceSpec");
    for d in devices {
        let id = d.get("id").and_then(Json::as_str).expect("device id");
        assert!(d.get("kind").and_then(Json::as_str).is_some(), "{id}: kind");
        assert!(d.get("tflops").and_then(Json::as_f64).unwrap() > 0.0, "{id}: peak FLOP/s");
        assert!(d.get("bandwidth_gbs").and_then(Json::as_f64).unwrap() > 0.0, "{id}: bw");
        assert!(d.get("mem_bytes").and_then(Json::as_f64).unwrap() > 0.0, "{id}: capacity");
        assert!(d.get("link_gbs").is_some() && d.get("model").is_some(), "{id}: spec fields");
    }
    let backends = doc.get("backends").and_then(Json::as_arr).expect("backends array");
    let registry = sol::backends::default_registry();
    assert_eq!(backends.len(), registry.len(), "one entry per registered backend");
    for b in backends {
        assert!(b.get("name").and_then(Json::as_str).is_some());
        assert!(b.get("device").and_then(Json::as_str).is_some());
        assert!(b.get("arena_exec").is_some() && b.get("offload").is_some());
        assert!(!b.get("pipeline").and_then(Json::as_arr).unwrap().is_empty());
    }
}

#[test]
fn sol_devices_lists_every_registered_backend_and_device() {
    // structural sanity independent of the golden text: every backend in
    // the default registry appears with its device and pipeline line
    let out = Command::new(env!("CARGO_BIN_EXE_sol"))
        .arg("devices")
        .output()
        .expect("run sol devices");
    let stdout = String::from_utf8(out.stdout).unwrap();
    let section = backend_section(&stdout);
    let registry = sol::backends::default_registry();
    for b in registry.iter() {
        assert!(section.contains(b.name()), "missing backend {}", b.name());
        assert!(
            section.contains(&format!("device={:?}", b.device())),
            "missing device for {}",
            b.name()
        );
        let pipeline = b.pipeline_names().join(" -> ");
        assert!(section.contains(&pipeline), "missing pipeline for {}", b.name());
    }
    assert_eq!(
        section.matches("pipeline:").count(),
        registry.len(),
        "one pipeline line per backend"
    );
}
