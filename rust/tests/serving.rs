//! Serving-layer acceptance tests: concurrent tenants over one bounded
//! `ServingSession` — artifact sharing, pin-aware eviction, admission
//! rejection, per-tenant metrics — plus the `#[ignore]`d deterministic
//! soak test CI's scheduled job runs (`cargo test -- --ignored`).

use std::sync::Arc;

use sol::devsim::DeviceId;
use sol::exec::solrun::OffloadMode;
use sol::metrics;
use sol::session::{
    AdmissionError, EvictionPolicy, Phase, ServingConfig, ServingSession,
};
use sol::workloads::NetId;

fn cfg(cache: usize, inflight: usize, resident: usize) -> ServingConfig {
    ServingConfig {
        cache_capacity: cache,
        eviction_policy: EvictionPolicy::Lru,
        max_inflight_compiles: inflight,
        max_resident_per_tenant: resident,
    }
}

/// Acceptance: two tenants compiling the same graph share one `Arc`
/// artifact — exactly one miss, one hit, attributed to the right tenants.
#[test]
fn shared_graph_compiles_once_across_tenants() {
    let serving = ServingSession::new(cfg(8, 4, 4));
    let alice = serving.tenant("alice");
    let bob = serving.tenant("bob");
    let g = NetId::Resnet18.build(1);
    let m_alice = alice.compile(&g, DeviceId::AuroraVE10B).unwrap();
    let m_bob = bob.compile(&g, DeviceId::AuroraVE10B).unwrap();
    assert!(Arc::ptr_eq(&m_alice, &m_bob), "tenants must share one artifact");
    let s = serving.cache_stats();
    assert_eq!((s.misses, s.hits, s.len), (1, 1, 1), "one miss, one hit, one entry");
    assert_eq!(alice.counters().compiles, 1);
    assert_eq!(alice.counters().cache_hits, 0, "first compile is the miss");
    assert_eq!(bob.counters().cache_hits, 1, "second tenant gets the hit");
    // both can execute over it with independent per-request executors
    let r1 = alice.run(&m_alice, OffloadMode::Native, Phase::infer());
    let r2 = bob.run(&m_bob, OffloadMode::Transparent, Phase::Infer { first_run: true });
    assert!(r1.total_us > 0.0 && r2.total_us > r1.total_us);
    assert_eq!((alice.counters().runs, bob.counters().runs), (1, 1));
}

/// Acceptance: under a tight capacity, eviction never drops an artifact
/// still held by a live executor or tenant pin.
#[test]
fn eviction_never_drops_an_artifact_in_use() {
    let serving = ServingSession::new(cfg(1, 4, 1));
    let t = serving.tenant("pinner");
    let g_used = NetId::Mlp.build(1);
    let used = t.compile(&g_used, DeviceId::Xeon6126).unwrap();
    let used_key = serving.session().compile_traced(&g_used, DeviceId::Xeon6126).key;
    // a live executor over the artifact — an extra pin beyond the tenant's
    let executor = t.executor(&used, OffloadMode::Native);
    // churn 3 other single-use graphs through the 1-entry cache; the
    // tenant's resident slot (capacity 1) moves on, the executor keeps
    // `used` pinned
    for b in [2usize, 4, 8] {
        let g = NetId::Mlp.build(b);
        t.compile(&g, DeviceId::Xeon6126).unwrap();
    }
    assert!(
        serving.session().cache().peek(&used_key).is_some(),
        "executor-held artifact must survive eviction pressure"
    );
    assert!(serving.cache_stats().evictions > 0, "churn must evict the unpinned ones");
    // the executor still runs fine over the shared artifact
    let report = serving.session().run(&executor, Phase::infer());
    assert!(report.total_us > 0.0);
    // once every pin is gone, the artifact becomes evictable
    drop(executor);
    drop(used);
    t.release_all();
    let evictions_before = serving.cache_stats().evictions;
    for b in [16usize, 32] {
        let g = NetId::Mlp.build(b);
        t.compile(&g, DeviceId::Xeon6126).unwrap();
    }
    assert!(serving.cache_stats().evictions > evictions_before);
    assert!(
        serving.session().cache().peek(&used_key).is_none(),
        "unpinned artifact is reclaimed under pressure"
    );
}

/// Acceptance: admission limits reject immediately — they never queue,
/// so overload cannot deadlock, and permits are released on drop.
#[test]
fn admission_rejects_excess_inflight_compiles() {
    let serving = ServingSession::new(cfg(8, 2, 4));
    let t = serving.tenant("greedy");
    let g = NetId::Mlp.build(1);
    let p1 = t.try_admit().unwrap();
    let p2 = t.try_admit().unwrap();
    assert_eq!(t.counters().inflight, 2);
    let err = t.compile(&g, DeviceId::Xeon6126).unwrap_err();
    assert_eq!(err, AdmissionError::InflightLimit { tenant: "greedy".into(), limit: 2 });
    // a different tenant has its own budget
    let other = serving.tenant("patient");
    assert!(other.compile(&g, DeviceId::Xeon6126).is_ok());
    // releasing permits restores admission
    drop(p1);
    drop(p2);
    assert_eq!(t.counters().inflight, 0);
    assert!(t.compile(&g, DeviceId::Xeon6126).is_ok());
}

/// Concurrent tenants hammering the same graph: every request either
/// succeeds or is cleanly rejected, exactly one compile happens, and the
/// threads always join (no deadlock under contention).
#[test]
fn concurrent_tenants_share_one_compile_without_deadlock() {
    let serving = ServingSession::new(cfg(8, 8, 4));
    let g = NetId::Squeezenet1_1.build(1);
    // pre-warm: the one real miss happens here, so every threaded lookup
    // below must hit the same Arc (a concurrent same-key double-miss may
    // legitimately produce two artifacts; that nondeterminism is not what
    // this test pins)
    let warm = serving.tenant("warmup").compile(&g, DeviceId::TitanV).unwrap();
    let models: Vec<Arc<sol::passes::OptimizedModel>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let tenant = serving.tenant(&format!("t{i}"));
                let g = g.clone();
                scope.spawn(move || {
                    let mut out = Vec::new();
                    for _ in 0..8 {
                        let m = tenant.compile(&g, DeviceId::TitanV).unwrap();
                        tenant.run(&m, OffloadMode::Native, Phase::infer());
                        out.push(m);
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    // all 32 threaded requests resolved to the pre-warmed artifact...
    assert_eq!(models.len(), 32);
    assert!(models.iter().all(|m| Arc::ptr_eq(m, &warm)));
    // ...through exactly one compile: 1 miss (warm-up) + 32 hits
    let s = serving.cache_stats();
    assert_eq!((s.hits, s.misses, s.len), (32, 1, 1));
    let runs: u64 = (0..4).map(|i| serving.tenant(&format!("t{i}")).counters().runs).sum();
    assert_eq!(runs, 32);
}

/// Acceptance: per-tenant counters surface in the process-wide metrics
/// registry under `serve.<tenant>.<counter>`.
#[test]
fn tenant_counters_reach_the_metrics_registry() {
    let serving = ServingSession::new(cfg(8, 4, 4));
    let t = serving.tenant("metered");
    let g = NetId::Mlp.build(1);
    let m = t.compile(&g, DeviceId::Xeon6126).unwrap();
    t.compile(&g, DeviceId::Xeon6126).unwrap();
    t.run(&m, OffloadMode::Native, Phase::infer());
    let snapshot = metrics::counters_snapshot();
    let get = |name: &str| {
        snapshot
            .iter()
            .find(|(k, _)| k == name)
            .unwrap_or_else(|| panic!("{name} missing from counters_snapshot"))
            .1
    };
    assert!(get("serve.metered.compiles") >= 2);
    assert!(get("serve.metered.cache_hits") >= 1);
    assert!(get("serve.metered.runs") >= 1);
    // the report renders the same numbers
    let report = serving.serving_report();
    assert!(report.contains("metered"), "{report}");
}

/// Deterministic serving soak: 1k requests round-robin across 4 tenants
/// with a 16-entry cache over 32 distinct content addresses.  Ignored for
/// tier-1 speed; CI's scheduled job runs it (`cargo test -- --ignored`).
#[test]
#[ignore = "soak test: ~1k compiles; run via cargo test -- --ignored"]
fn soak_1k_requests_4_tenants_bounded_cache() {
    use sol::util::XorShift;
    let serving = ServingSession::new(ServingConfig {
        cache_capacity: 16,
        eviction_policy: EvictionPolicy::Lru,
        max_inflight_compiles: 4,
        max_resident_per_tenant: 4,
    });
    // 8 small nets x 4 devices = 32 distinct keys, double the capacity
    let nets = [
        NetId::Resnet18,
        NetId::Squeezenet1_0,
        NetId::Squeezenet1_1,
        NetId::ShufflenetV2X0_5,
        NetId::ShufflenetV2X1_0,
        NetId::Mnasnet0_5,
        NetId::Mnasnet1_0,
        NetId::Mlp,
    ];
    let tenants: Vec<_> = (0..4).map(|i| serving.tenant(&format!("soak-{i}"))).collect();
    let mut rng = XorShift::new(7);
    const REQUESTS: usize = 1000;
    for r in 0..REQUESTS {
        let tenant = &tenants[r % tenants.len()];
        let net = *rng.pick(&nets);
        let dev = DeviceId::ALL[rng.below(DeviceId::ALL.len())];
        let g = net.build(1);
        // single-threaded round-robin: admission never trips, every
        // request must succeed and execute
        let model = tenant.compile(&g, dev).unwrap();
        let report = tenant.run(&model, OffloadMode::Native, Phase::infer());
        assert!(report.total_us > 0.0, "request {r} produced no work");
    }
    let s = serving.cache_stats();
    // exact accounting: every request was one hit or one miss, every miss
    // inserted, and len is what survived eviction
    assert_eq!(s.hits + s.misses, REQUESTS as u64);
    assert_eq!(s.len as u64, s.misses - s.evictions, "insert/evict accounting must balance");
    assert!(s.evictions > 0, "32-key working set over a 16-entry cache must evict");
    // hit-rate bounds: residency guarantees a floor well above cold-start,
    // the over-capacity working set keeps it well below perfect
    let hit_rate = s.hits as f64 / REQUESTS as f64;
    assert!(hit_rate > 0.25, "hit rate {hit_rate:.3} implausibly low");
    assert!(hit_rate < 0.95, "hit rate {hit_rate:.3} implausibly high for 2x working set");
    // per-tenant accounting sums to the whole
    let totals: u64 = tenants.iter().map(|t| t.counters().compiles).sum();
    assert_eq!(totals, REQUESTS as u64);
    let runs: u64 = tenants.iter().map(|t| t.counters().runs).sum();
    assert_eq!(runs, REQUESTS as u64);
    for t in &tenants {
        let c = t.counters();
        assert!(c.resident <= 4, "tenant {} resident {} over cap", t.name(), c.resident);
        assert!(c.evicted > 0, "tenant {} never recycled its resident set", t.name());
        assert_eq!(c.inflight, 0);
    }
}
