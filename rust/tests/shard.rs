//! Acceptance tests for the cross-device sharding engine (`sol::shard`).
//!
//! The deterministic fig3 test pins the ISSUE contract: over a
//! two-device registry every shard fits its device's memory, the total
//! estimated makespan (including transfer cost) never loses to the best
//! single-device estimate in auto-depth mode (or the report says why),
//! and the sharded execution is output-equivalent to the unsharded
//! reference within the audit tolerance.  The seeded property sweep
//! extends the equivalence claim over random modules × device subsets ×
//! stage counts (small tier-1 sample; the `#[ignore]`d full sweep runs
//! in the nightly soak).

use sol::audit::TolerancePolicy;
use sol::devsim::DeviceId;
use sol::exec::kernelbench::fig3_cnn_module;
use sol::framework::{install_default, Tensor};
use sol::frontend::{extract_graph, naive_forward, SolModel};
use sol::session::Session;
use sol::shard::{plan_shards, ShardConfig, ShardedExec};
use sol::util::gen::random_module;
use sol::util::XorShift;

const TOL: TolerancePolicy = TolerancePolicy::new(1e-6, 1e-4, 4);

fn assert_close(got: &Tensor, want: &Tensor, ctx: &str) {
    let a = got.to_f32().expect("sharded output as f32");
    let b = want.to_f32().expect("reference output as f32");
    assert_eq!(a.len(), b.len(), "{ctx}: output size mismatch");
    for (i, (&x, &y)) in a.iter().zip(&b).enumerate() {
        assert!(TOL.accepts(x, y), "{ctx}: element {i} diverged (sharded {x} vs reference {y})");
    }
}

/// The ISSUE acceptance criterion, as one deterministic test.
#[test]
fn fig3_two_device_plan_fits_prices_honestly_and_matches_the_reference() {
    let (module, shape) = fig3_cnn_module();
    let (g, binding) = extract_graph(&module, &shape, "fig3_cnn").expect("extract fig3");
    let session = Session::new();
    let cfg = ShardConfig {
        devices: vec![DeviceId::Xeon6126, DeviceId::TitanV],
        ..ShardConfig::default()
    };
    let plan = plan_shards(&session, &g, &cfg).expect("plan fig3");

    // every shard fits its assigned device's memory capacity
    assert!(plan.memory_fits());
    for s in &plan.stages {
        assert!(s.mem_required > 0, "stage {} allocated nothing", s.index);
        assert!(
            s.mem_required <= s.mem_capacity,
            "stage {} needs {} B but {:?} caps at {} B",
            s.index,
            s.mem_required,
            s.device,
            s.mem_capacity
        );
    }

    // makespan <= best single-device estimate, or the report explains
    // why not; in auto-depth mode the 1-stage plan is a candidate priced
    // identically, so beating the single bound is guaranteed
    let single = plan.single.as_ref().expect("both devices fit the whole fig3 CNN");
    assert!(
        plan.est_total_us <= single.est_us * (1.0 + 1e-9) + 1e-6,
        "auto-depth plan ({:.3}µs) lost to {:?} alone ({:.3}µs)",
        plan.est_total_us,
        single.device,
        single.est_us
    );
    assert!(plan.beats_single);
    assert!(plan.reason.is_none(), "a winning plan needs no excuse: {:?}", plan.reason);

    // boundaries are priced end to end: host feed first, host drain last
    assert_eq!(plan.transfers.first().expect("host input edge").from_stage, None);
    assert_eq!(plan.transfers.last().expect("host output edge").to_stage, None);
    assert!(plan.est_total_us > 0.0);

    // sharded execution is output-equivalent to the unsharded reference
    let exec = ShardedExec::build(&session, &plan, &binding).expect("build sharded exec");
    assert_eq!(exec.stage_count(), plan.stages.len());
    let x = Tensor::randn(&shape, 42, 0.5);
    let sharded = exec.forward(&x).expect("sharded forward");
    let reference =
        SolModel::optimize_in(&session, &module, &shape, "fig3_cnn", DeviceId::Xeon6126)
            .expect("unsharded reference model")
            .forward(&x)
            .expect("reference forward");
    assert_close(&sharded, &reference, "fig3 sharded vs unsharded");
}

/// A warm re-plan of the same graph is all cache hits, and per-shard
/// artifacts stay out of the cache's "models resident" figure.
#[test]
fn warm_replan_is_all_cache_hits_and_shards_are_counted_apart() {
    let (module, shape) = fig3_cnn_module();
    let (g, _binding) = extract_graph(&module, &shape, "fig3_cnn").expect("extract fig3");
    let session = Session::new();
    let cfg = ShardConfig {
        devices: vec![DeviceId::Xeon6126, DeviceId::TitanV],
        stages: Some(2),
        ..ShardConfig::default()
    };
    let cold = plan_shards(&session, &g, &cfg).expect("cold plan");
    assert_eq!(cold.stages.len(), 2);
    let warm = plan_shards(&session, &g, &cfg).expect("warm plan");

    // deterministic: identical cuts, devices and estimates
    assert_eq!(cold.cuts, warm.cuts);
    let devs =
        |p: &sol::shard::ShardPlan| p.stages.iter().map(|s| s.device).collect::<Vec<_>>();
    assert_eq!(devs(&cold), devs(&warm));
    assert_eq!(cold.est_total_us, warm.est_total_us);

    // warm pass: every stage artifact came out of the compile cache
    assert!(
        warm.stages.iter().all(|s| s.cache_hit),
        "warm re-plan must hit for every stage"
    );

    // 2 stage ranges x 2 devices are shard-tagged; the 2 whole-graph
    // single-device estimates are ordinary model entries
    let stats = session.cache().stats();
    assert_eq!(stats.shards, 4, "stage artifacts must be tagged as shards");
    assert_eq!(stats.models(), stats.len - stats.shards);
    assert_eq!(stats.models(), 2, "the single-device baselines are models, not shards");
}

/// Capacity pressure: when no single device can hold the whole model,
/// the planner must still find a multi-stage placement and say that
/// sharding is required.
#[test]
fn memory_pressure_forces_a_sharded_placement() {
    let (module, shape) = fig3_cnn_module();
    let (g, _binding) = extract_graph(&module, &shape, "fig3_cnn").expect("extract fig3");
    let session = Session::new();
    let devices = vec![DeviceId::Xeon6126, DeviceId::TitanV];
    let base = plan_shards(
        &session,
        &g,
        &ShardConfig { devices: devices.clone(), stages: Some(2), ..ShardConfig::default() },
    )
    .expect("unrestricted 2-stage plan");
    let max_req = base.stages.iter().map(|s| s.mem_required).max().unwrap();

    // admit each stage alone but not the whole model on one device
    let capped = plan_shards(
        &session,
        &g,
        &ShardConfig {
            devices: devices.clone(),
            stages: None,
            mem_cap: Some(max_req + 4096),
            replicate: true,
        },
    )
    .expect("capped plan");
    assert!(capped.stages.len() >= 2, "one device cannot hold the whole model");
    assert!(capped.memory_fits());
    assert!(capped.single.is_none(), "no single device may fit under the cap");
    assert!(capped.beats_single, "with no single-device bound the plan stands");
    let reason = capped.reason.as_deref().expect("required sharding carries a reason");
    assert!(reason.contains("sharding is required"), "unexpected reason: {reason}");

    // a cap below every stage's own requirement is honestly infeasible
    let min_req = base.stages.iter().map(|s| s.mem_required).min().unwrap();
    let err = plan_shards(
        &session,
        &g,
        &ShardConfig {
            devices,
            stages: Some(2),
            mem_cap: Some(min_req / 2),
            replicate: true,
        },
    )
    .expect_err("nothing fits half the smallest stage");
    assert!(err.to_string().contains("no feasible placement"), "unexpected error: {err}");
}

/// `shard.plans` advances on every planning call (serving_report surfaces
/// the `shard.*` family).
#[test]
fn planning_bumps_the_shard_metrics() {
    let (module, shape) = fig3_cnn_module();
    let (g, _binding) = extract_graph(&module, &shape, "fig3_cnn").expect("extract fig3");
    let before = sol::metrics::counter("shard.plans").get();
    let session = Session::new();
    plan_shards(
        &session,
        &g,
        &ShardConfig {
            devices: vec![DeviceId::Xeon6126, DeviceId::TitanV],
            stages: Some(2),
            ..ShardConfig::default()
        },
    )
    .expect("plan");
    assert!(sol::metrics::counter("shard.plans").get() > before);
    assert!(sol::metrics::counter("shard.stages").get() >= 1);
}

/// Seeded property: sharded execution matches the naive framework
/// reference over random modules × device registries × stage counts.
fn equivalence_sweep(seeds: u64) {
    let kernels = install_default();
    let device_sets: [&[DeviceId]; 3] = [
        &[DeviceId::Xeon6126],
        &[DeviceId::Xeon6126, DeviceId::TitanV],
        &[DeviceId::Xeon6126, DeviceId::AuroraVE10B, DeviceId::QuadroP4000],
    ];
    for seed in 0..seeds {
        let (module, shape) = random_module(&mut XorShift::new(seed));
        let name = format!("shard-prop-{seed}");
        let (g, binding) = extract_graph(&module, &shape, &name).expect("extract");
        let x = Tensor::randn(&shape, seed ^ 0xDEAD_BEEF, 0.5);
        let reference = naive_forward(&g, &binding, &x, &kernels).expect("reference");
        for devices in device_sets {
            let session = Session::new();
            for stages in [2usize, 3] {
                let cfg = ShardConfig {
                    devices: devices.to_vec(),
                    stages: Some(stages),
                    ..ShardConfig::default()
                };
                let ctx = format!("seed {seed}, {devices:?}, {stages} stages");
                let plan = plan_shards(&session, &g, &cfg)
                    .unwrap_or_else(|e| panic!("{ctx}: planning failed: {e}"));
                assert!(plan.memory_fits(), "{ctx}: placement must fit");
                let exec = ShardedExec::build(&session, &plan, &binding)
                    .unwrap_or_else(|e| panic!("{ctx}: exec build failed: {e}"));
                let got =
                    exec.forward(&x).unwrap_or_else(|e| panic!("{ctx}: forward failed: {e}"));
                assert_close(&got, &reference, &ctx);
            }
        }
    }
}

#[test]
fn random_modules_shard_equivalently_sample() {
    equivalence_sweep(3);
}

/// The nightly-soak tier (`cargo test --release -- --ignored`).
#[test]
#[ignore = "full seeded equivalence sweep; run in the nightly soak"]
fn random_modules_shard_equivalently_full() {
    equivalence_sweep(12);
}
