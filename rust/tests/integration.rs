//! Cross-layer integration tests: the rust coordinator driving real PJRT
//! executions of the AOT artifacts (L1 Pallas kernels inside L2 jax
//! graphs), plus whole-stack frontend flows.
//!
//! These tests need `make artifacts` to have run; they skip (with a
//! message) when the artifacts are absent so `cargo test` stays green in
//! a fresh checkout.

use sol::devsim::DeviceId;
use sol::framework::{install_default, Module, Tensor};
use sol::frontend::{install_native_backend, SolModel, TransparentOffload};
use sol::passes::OptimizeOptions;
use sol::runtime::pjrt::{HostTensor, PjrtEngine};
use sol::util::XorShift;

fn engine() -> Option<PjrtEngine> {
    match PjrtEngine::new() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping PJRT test: {e}");
            None
        }
    }
}

fn close(a: &[f32], b: &[f32], tol: f32) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "elem {i}: {x} vs {y}"
        );
    }
}

/// The SOL-fused conv block and the stock per-op chain must agree:
/// fused pallas kernel vs conv->bias_relu->maxpool as separate executables.
#[test]
fn fused_conv_site_matches_per_op_chain() {
    let Some(e) = engine() else { return };
    let mut rng = XorShift::new(21);
    let x = rng.normal_vec(58 * 58 * 64, 0.1);
    let w = rng.normal_vec(3 * 3 * 64 * 64, 0.1);
    let b = rng.normal_vec(64, 0.1);

    let fused = e.run_f32("conv_site_sol_b1", &[x.clone(), w.clone(), b.clone()]).unwrap();

    let conv = e.run_f32("op_conv3x3_cb_b1", &[x, w]).unwrap();
    let br = e
        .run_f32("op_bias_relu_cb_b1", &[conv[0].as_f32().unwrap().to_vec(), b])
        .unwrap();
    let pool = e
        .run_f32("op_maxpool_cb_b1", &[br[0].as_f32().unwrap().to_vec()])
        .unwrap();

    close(fused[0].as_f32().unwrap(), pool[0].as_f32().unwrap(), 1e-3);
}

/// SOL variant == reference variant for every paired artifact we ship.
#[test]
fn sol_and_ref_artifacts_agree() {
    let Some(e) = engine() else { return };
    let mut rng = XorShift::new(22);
    for (sol_e, shapes) in [
        ("dw_site_sol_b1", vec![vec![1usize, 58, 58, 128], vec![3, 3, 128], vec![128]]),
        ("avgpool_sol", vec![vec![512, 130, 130]]),
    ] {
        let ref_e = sol_e.replace("_sol", "_ref");
        let inputs: Vec<Vec<f32>> = shapes
            .iter()
            .map(|s| rng.normal_vec(s.iter().product(), 0.2))
            .collect();
        let a = e.run_f32(sol_e, &inputs).unwrap();
        let b = e.run_f32(&ref_e, &inputs).unwrap();
        close(a[0].as_f32().unwrap(), b[0].as_f32().unwrap(), 1e-3);
    }
}

/// Full CNN inference: the DFP-fused graph equals the reference graph.
#[test]
fn cnn_infer_sol_matches_ref() {
    let Some(e) = engine() else { return };
    let mut rng = XorShift::new(23);
    let shapes: Vec<Vec<usize>> = vec![
        vec![3, 3, 3, 32], vec![32], vec![3, 3, 32, 64], vec![64],
        vec![4096, 256], vec![256], vec![256, 10], vec![10],
        vec![1, 32, 32, 3],
    ];
    let inputs: Vec<Vec<f32>> =
        shapes.iter().map(|s| rng.normal_vec(s.iter().product(), 0.1)).collect();
    let a = e.run_f32("cnn_infer_sol_b1", &inputs).unwrap();
    let b = e.run_f32("cnn_infer_ref_b1", &inputs).unwrap();
    close(a[0].as_f32().unwrap(), b[0].as_f32().unwrap(), 2e-3);
}

/// One SOL training step == one reference training step (params + loss),
/// despite the different forward implementation (custom_vjp fused fwd).
#[test]
fn cnn_train_step_sol_matches_ref() {
    let Some(e) = engine() else { return };
    let mut rng = XorShift::new(24);
    let shapes: Vec<Vec<usize>> = vec![
        vec![3, 3, 3, 32], vec![32], vec![3, 3, 32, 64], vec![64],
        vec![4096, 256], vec![256], vec![256, 10], vec![10],
    ];
    let mut inputs: Vec<HostTensor> = shapes
        .iter()
        .map(|s| HostTensor::F32(rng.normal_vec(s.iter().product(), 0.05)))
        .collect();
    inputs.push(HostTensor::F32(rng.normal_vec(32 * 32 * 32 * 3, 0.5)));
    inputs.push(HostTensor::I32((0..32).map(|i| i % 10).collect()));

    let a = e.run("cnn_train_sol_b32", &inputs).unwrap();
    let b = e.run("cnn_train_ref_b32", &inputs).unwrap();
    assert_eq!(a.len(), 9); // 8 updated params + loss
    for (x, y) in a.iter().zip(&b) {
        close(x.as_f32().unwrap(), y.as_f32().unwrap(), 5e-3);
    }
}

/// MLP training through PJRT actually learns on a separable problem.
#[test]
fn mlp_training_loss_decreases() {
    let Some(e) = engine() else { return };
    let entry = "mlp_train_sol_b16";
    let sig = e.manifest.entry(entry).unwrap().clone();
    let mut rng = XorShift::new(25);
    let mut params: Vec<HostTensor> = sig.inputs[..6]
        .iter()
        .map(|s| {
            let scale = if s.shape.len() == 2 { 0.01 } else { 0.0 };
            HostTensor::F32(rng.normal_vec(s.elems(), scale))
        })
        .collect();
    let mut losses = Vec::new();
    for _ in 0..4 {
        let labels: Vec<i32> = (0..16).map(|i| i % 10).collect();
        let mut x = rng.normal_vec(16 * 8192, 0.1);
        for (i, &l) in labels.iter().enumerate() {
            for j in 0..64 {
                x[i * 8192 + (l as usize) * 64 + j] += 1.0;
            }
        }
        let mut inputs = params.clone();
        inputs.push(HostTensor::F32(x));
        inputs.push(HostTensor::I32(labels));
        let mut out = e.run(entry, &inputs).unwrap();
        losses.push(out.pop().unwrap().scalar_f32().unwrap());
        params = out;
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "{losses:?}"
    );
}

/// Whole-stack transparent offloading on an extracted framework model,
/// with numerics checked against the framework's own execution.
#[test]
fn transparent_offload_full_stack() {
    let py_model = Module::Sequential(vec![
        Module::conv2d(3, 8, 3, 1, 1, 31),
        Module::ReLU,
        Module::MaxPool2d { k: 2, stride: 2, pad: 0 },
        Module::Flatten,
        Module::linear(8 * 8 * 8, 10, 32),
    ]);
    let reg = install_default();
    let x = Tensor::randn(&[1, 3, 16, 16], 33, 0.5);
    let want = py_model.forward(&reg, &x).unwrap().to_f32().unwrap();

    let sol = SolModel::optimize(
        &py_model,
        &[1, 3, 16, 16],
        "it",
        &OptimizeOptions::new(DeviceId::AuroraVE10B),
    )
    .unwrap();
    let mut to = TransparentOffload::set_device(DeviceId::AuroraVE10B);
    let got = to.forward(&sol, &x).unwrap().to_f32().unwrap();
    close(&want, &got, 1e-4);
    assert_eq!(to.param_uploads, 1);
}

/// Native offloading: a DenseNet-style block runs on hip:0 through the
/// unmodified framework dispatcher.
#[test]
fn native_offload_dense_block() {
    let mut reg = install_default();
    let be = install_native_backend(&mut reg).unwrap();
    let m = Module::Sequential(vec![
        Module::DenseBlock(vec![
            Module::conv2d(4, 4, 3, 1, 1, 41),
            Module::conv2d(8, 4, 3, 1, 1, 42),
        ]),
        Module::ReLU,
        Module::GlobalAvgPool,
    ]);
    let x = Tensor::randn(&[2, 4, 8, 8], 43, 0.5);
    let want = m.forward(&reg, &x).unwrap().to_f32().unwrap();
    let got = be
        .to_host(&m.forward(&reg, &be.to_device(&x).unwrap()).unwrap())
        .unwrap()
        .to_f32()
        .unwrap();
    close(&want, &got, 1e-5);
}

/// The deployment bundle serves real PJRT inference with zero framework
/// involvement.
#[test]
fn deployment_bundle_serves() {
    let Ok(manifest) =
        sol::runtime::manifest::Manifest::load(sol::runtime::manifest::Manifest::default_dir())
    else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    use sol::passes::optimize;
    use sol::workloads::NetId;
    let model = optimize(&NetId::Squeezenet1_1.build(1), &OptimizeOptions::new(DeviceId::Xeon6126));
    let dir = std::env::temp_dir().join(format!("sol_it_bundle_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    sol::deploy::write_bundle(&model, &["cnn_infer_sol_b1"], &manifest, &dir).unwrap();
    let dep = sol::deploy::DeployedModel::load(&dir).unwrap();
    let mut rng = XorShift::new(55);
    let mut inputs: Vec<Vec<f32>> = [
        vec![3usize, 3, 3, 32], vec![32], vec![3, 3, 32, 64], vec![64],
        vec![4096, 256], vec![256], vec![256, 10], vec![10],
    ]
    .iter()
    .map(|s| rng.normal_vec(s.iter().product(), 0.1))
    .collect();
    inputs.push(rng.normal_vec(32 * 32 * 3, 1.0));
    let out = dep.run_f32("cnn_infer_sol_b1", &inputs).unwrap();
    assert_eq!(out[0].as_f32().unwrap().len(), 10);
    std::fs::remove_dir_all(&dir).unwrap();
}
