//! Acceptance tests for the spine's drain-accounting fixes and the
//! latency-aware adaptive batching & placement policy — all driven in
//! manual-pump mode (`workers: 0`) on the spine's virtual clock, so
//! every assertion is deterministic (no sleeps, no timing flakes).
//!
//! The four regression tests pin behaviors that were wrong before this
//! change and would fail against the pre-fix spine:
//! * an already-expired deadline used to be *enqueued* (burning a queue
//!   slot until a drain noticed) — now rejected at submit;
//! * a failed batch used to vanish from the accounting (no counter, no
//!   latency, no tenant attribution) — now `failed` counts it and the
//!   histogram records it;
//! * `queue_us` used to be `total_us - exec_us`, charging batch
//!   assembly to "queued" — now it is enqueue → batch start, measured;
//! * same-key coalescing used to `VecDeque::remove` in a scan — the
//!   single-pass rewrite must preserve non-batched requests' relative
//!   order (property-tested over random interleavings).

use std::time::Duration;

use sol::audit::fixed_workloads;
use sol::backends::{BackendRegistry, Capabilities, DeviceBackend};
use sol::devsim::DeviceId;
use sol::dfp::Flavor;
use sol::dnn::Library;
use sol::exec::kernelbench::validate_bench_json;
use sol::exec::servebench::{run_policy_ab, write_policy_ab_json, ServeBenchConfig};
use sol::framework::DeviceType;
use sol::frontend::extract_graph;
use sol::session::{
    AdmissionError, DrainOutcome, ServingConfig, ServingSession, Session, SpineConfig,
    SpinePolicy,
};
use sol::util::{Json, XorShift};

const HOST: DeviceId = DeviceId::Xeon6126;

fn assert_close(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + a.abs().max(b.abs())),
            "{ctx}: elem {i}: {a} vs {b}"
        );
    }
}

/// A manual-pump spine under `policy` over the default registry.
fn pump_spine(cfg: SpineConfig) -> ServingSession {
    assert_eq!(cfg.workers, 0, "policy tests must stay deterministic");
    let serving = ServingSession::new(ServingConfig::default());
    serving.spine_with(cfg);
    serving
}

fn adaptive(queue_depth: usize, max_batch: usize, hold_us: u64) -> SpineConfig {
    SpineConfig {
        workers: 0,
        queue_depth,
        max_batch,
        policy: SpinePolicy::Adaptive,
        hold_us,
        ..SpineConfig::default()
    }
}

fn fifo(queue_depth: usize, max_batch: usize) -> SpineConfig {
    SpineConfig { workers: 0, queue_depth, max_batch, ..SpineConfig::default() }
}

// ---------------------------------------------------------------------
// regression: expired-at-submit rejection
// ---------------------------------------------------------------------

/// A request whose deadline is already unmeetable at submit time is
/// rejected at the door — it never occupies a queue slot, never counts
/// as submitted, and the waiterless caller hears `DeadlineExceeded`
/// immediately.  (Pre-fix, the submit succeeded and the dead request
/// burned `queue_depth` until a drain discovered it.)
#[test]
fn already_expired_deadlines_reject_at_submit() {
    let serving = pump_spine(fifo(4, 2));
    let wl = &fixed_workloads()[2]; // mlp
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mlp").unwrap();
    let t = serving.tenant("door");
    let art = t.load_artifact(&g, &b, HOST).unwrap();
    let x = vec![0.1f32; art.input_len()];

    let err = t.submit(&art, x.clone(), Some(Duration::ZERO)).unwrap_err();
    assert_eq!(err, AdmissionError::DeadlineExceeded { waited_us: 0 });
    let st = serving.spine().stats();
    assert_eq!((st.submitted, st.queued, st.expired), (0, 0, 1), "never enqueued");

    // a meetable deadline is accepted and served as usual
    let h = t.submit(&art, x, Some(Duration::from_secs(60))).unwrap();
    assert_eq!(serving.spine().stats().queued, 1);
    assert_eq!(serving.spine().drain_one(HOST), 1);
    assert!(h.wait().is_ok());
}

// ---------------------------------------------------------------------
// regression: failure-path accounting
// ---------------------------------------------------------------------

/// A failed batch is *accounted* traffic: every request in it increments
/// `failed`, records end-to-end latency, and is attributed to its
/// tenant's `runs` — and every waiter resolves with the error.
/// (Pre-fix, the error path updated nothing: no counter, no histogram
/// sample, no tenant attribution.)
#[test]
fn failed_batches_are_counted_and_recorded() {
    // max_retries: 0 disables the degradation ladder — this test pins
    // the bare failure-accounting path (the resilience tests own the
    // bisection/rescue behavior)
    let serving = pump_spine(SpineConfig { max_retries: 0, ..fifo(8, 4) });
    let wl = &fixed_workloads()[2];
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mlp").unwrap();
    let t = serving.tenant("faulty");
    let art = t.load_artifact(&g, &b, HOST).unwrap();
    let x = vec![0.2f32; art.input_len()];

    let h1 = t.submit(&art, x.clone(), None).unwrap();
    let h2 = t.submit(&art, x.clone(), None).unwrap();
    serving.spine().fail_next_batches_for_tests(1);
    assert_eq!(serving.spine().drain_one(HOST), 2, "both requests resolved");
    for h in [h1, h2] {
        match h.wait() {
            Err(AdmissionError::Failed { reason }) => {
                assert!(reason.contains("injected"), "{reason}")
            }
            other => panic!("expected Failed, got {other:?}"),
        }
    }
    let st = serving.spine().stats();
    assert_eq!((st.failed, st.completed, st.queued), (2, 0, 0));
    assert_eq!(serving.spine().latency().count(), 2, "failed latency is recorded");
    assert_eq!(t.counters().runs, 2, "failed submissions attribute to the tenant");

    // the injection is consumed: the next batch succeeds normally
    let h = t.submit(&art, x, None).unwrap();
    assert_eq!(serving.spine().drain_one(HOST), 1);
    assert!(h.wait().is_ok());
    let st = serving.spine().stats();
    assert_eq!((st.failed, st.completed), (2, 1));
    assert_eq!(serving.spine().latency().count(), 3);
}

// ---------------------------------------------------------------------
// regression: honest queue_us decomposition
// ---------------------------------------------------------------------

/// `queue_us` measures enqueue → batch start, per request; batch
/// assembly lands only in the `total - queue - exec` gap.  (Pre-fix,
/// `queue_us = total_us - exec_us`, so 200ms of simulated assembly
/// would have been reported as queueing.)
#[test]
fn queue_us_excludes_batch_assembly_time() {
    let serving = pump_spine(fifo(4, 2));
    let wl = &fixed_workloads()[2];
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mlp").unwrap();
    let t = serving.tenant("honest");
    let art = t.load_artifact(&g, &b, HOST).unwrap();

    let h = t.submit(&art, vec![0.3f32; art.input_len()], None).unwrap();
    // 300ms queued, then 200ms of (virtual) batch-assembly cost
    serving.spine().advance_clock_us(300_000);
    serving.spine().set_assembly_advance_us_for_tests(200_000);
    assert_eq!(serving.spine().drain_one(HOST), 1);
    serving.spine().set_assembly_advance_us_for_tests(0);

    let out = h.wait().unwrap();
    assert!(out.queue_us >= 300_000.0, "queued 300ms, reported {}", out.queue_us);
    assert!(
        out.queue_us < 400_000.0,
        "assembly must not be charged to queueing (queue_us {})",
        out.queue_us
    );
    assert!(out.total_us >= 500_000.0, "total spans queue + assembly ({})", out.total_us);
    let gap = out.total_us - out.queue_us - out.exec_us;
    assert!(gap >= 199_000.0, "the assembly cost must appear in the gap (gap {gap})");
}

// ---------------------------------------------------------------------
// adaptive policy: hold-for-µs coalescing window
// ---------------------------------------------------------------------

/// A lone request holds for the coalescing window instead of executing
/// at batch=1; the window elapses on the virtual clock and the request
/// then runs.  A full target batch never holds.  `drain_device` forces
/// through an open window (the flush path).
#[test]
fn lone_requests_hold_for_the_window_then_execute() {
    let serving = pump_spine(adaptive(16, 4, 1_000_000));
    let wl = &fixed_workloads()[2];
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mlp").unwrap();
    let t = serving.tenant("holder");
    let art = t.load_artifact(&g, &b, HOST).unwrap();
    let x = vec![0.4f32; art.input_len()];

    let h = t.submit(&art, x.clone(), None).unwrap();
    match serving.spine().pump(HOST) {
        DrainOutcome::Held { remaining_us } => {
            assert!(remaining_us > 0 && remaining_us <= 1_000_000, "{remaining_us}");
        }
        other => panic!("a lone under-filled batch must hold, got {other:?}"),
    }
    assert!(!h.is_done(), "held requests stay queued");
    assert_eq!(serving.spine().stats().held, 1);

    // the window elapses (virtually): the same pump now executes
    serving.spine().advance_clock_us(1_000_000);
    assert_eq!(serving.spine().pump(HOST), DrainOutcome::Completed(1));
    assert_eq!(h.wait().unwrap().batch_size, 1);

    // a full target batch executes immediately — no hold
    let hs: Vec<_> = (0..4).map(|_| t.submit(&art, x.clone(), None).unwrap()).collect();
    assert_eq!(serving.spine().pump(HOST), DrainOutcome::Completed(4));
    for h in hs {
        assert_eq!(h.wait().unwrap().batch_size, 4);
    }
    assert_eq!(serving.spine().stats().held, 1, "no further holds");

    // drain_device forces through an open window
    let h = t.submit(&art, x, None).unwrap();
    assert_eq!(serving.spine().drain_device(HOST), 1);
    assert!(h.wait().is_ok());
}

/// The hold window never outlasts the anchor's deadline: when the
/// anchor's slack is smaller than the window, the hold is bounded by
/// the slack — and once the deadline passes, the request expires (via
/// `DeadlineExceeded`) instead of holding forever.
#[test]
fn hold_window_is_capped_by_the_anchor_deadline() {
    // 60s window, but the lone request only has 30s of slack
    let serving = pump_spine(adaptive(16, 4, 60_000_000));
    let wl = &fixed_workloads()[2];
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mlp").unwrap();
    let t = serving.tenant("capped");
    let art = t.load_artifact(&g, &b, HOST).unwrap();

    let h = t
        .submit(&art, vec![0.5f32; art.input_len()], Some(Duration::from_secs(30)))
        .unwrap();
    match serving.spine().pump(HOST) {
        DrainOutcome::Held { remaining_us } => {
            assert!(
                remaining_us <= 30_000_000,
                "the deadline slack, not the 60s window, bounds the hold: {remaining_us}"
            );
        }
        other => panic!("expected a hold, got {other:?}"),
    }
    // past the deadline the request must resolve, not hold: slack is 0,
    // so the drain proceeds and rejects it as expired
    serving.spine().advance_clock_us(31_000_000);
    assert_eq!(serving.spine().pump(HOST), DrainOutcome::Completed(1));
    match h.wait() {
        Err(AdmissionError::DeadlineExceeded { waited_us }) => {
            assert!(waited_us >= 30_000_000, "{waited_us}");
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert_eq!(serving.spine().stats().expired, 1);
}

// ---------------------------------------------------------------------
// adaptive policy: deadline-sorted batch assembly
// ---------------------------------------------------------------------

/// Under the adaptive policy the tightest-deadline request anchors the
/// batch and same-key peers are taken in deadline order — near-expiry
/// requests are never passed over.  Under FIFO the same queue drains
/// front-first (the pre-policy behavior, kept bit-for-bit).
#[test]
fn deadline_sorted_assembly_never_passes_over_urgent_requests() {
    let wl = &fixed_workloads()[2];
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mlp").unwrap();

    // adaptive: the undeadlined front request yields to the urgent pair
    let serving = pump_spine(adaptive(16, 2, 0));
    let t = serving.tenant("sorted");
    let art = t.load_artifact(&g, &b, HOST).unwrap();
    let x = vec![0.6f32; art.input_len()];
    let a = t.submit(&art, x.clone(), None).unwrap(); // front, no deadline
    let b_h = t.submit(&art, x.clone(), Some(Duration::from_secs(10))).unwrap();
    let c = t.submit(&art, x.clone(), Some(Duration::from_secs(1))).unwrap(); // tightest
    assert_eq!(serving.spine().pump(HOST), DrainOutcome::Completed(2));
    let (ob, oc) = (b_h.wait().unwrap(), c.wait().unwrap());
    assert_eq!((ob.batch_size, oc.batch_size), (2, 2), "the urgent pair batched");
    assert!(!a.is_done(), "the undeadlined request waits its turn");
    assert_eq!(serving.spine().pump(HOST), DrainOutcome::Completed(1));
    assert_eq!(a.wait().unwrap().batch_size, 1);

    // FIFO control: the identical queue drains front-first instead
    let serving = pump_spine(fifo(16, 2));
    let t = serving.tenant("fifo-control");
    let art = t.load_artifact(&g, &b, HOST).unwrap();
    let a = t.submit(&art, x.clone(), None).unwrap();
    let b_h = t.submit(&art, x.clone(), Some(Duration::from_secs(10))).unwrap();
    let c = t.submit(&art, x, Some(Duration::from_secs(1))).unwrap();
    assert_eq!(serving.spine().drain_one(HOST), 2);
    assert!(a.is_done() && b_h.is_done(), "FIFO takes the front two");
    assert!(!c.is_done(), "…and passes over the urgent request");
    serving.spine().drain_one(HOST);
    assert!(c.wait().is_ok());
}

// ---------------------------------------------------------------------
// adaptive policy: per-artifact batch-size controller wiring
// ---------------------------------------------------------------------

/// `SpineConfig`'s SLO/cadence knobs reach the per-artifact controller,
/// and the drain honors the tuned target: after latency data narrows an
/// artifact's target to 1, a lone request executes immediately — the
/// hold window no longer waits for peers that latency says not to want.
#[test]
fn controller_narrowing_disables_the_hold_for_lone_requests() {
    let mut cfg = adaptive(16, 8, 1_000_000);
    cfg.slo_p95_us = 1_000;
    cfg.adjust_every = 4;
    let serving = pump_spine(cfg);
    let wl = &fixed_workloads()[2];
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mlp").unwrap();
    let t = serving.tenant("tuner");
    let art = t.load_artifact(&g, &b, HOST).unwrap();
    let ctl = art.controller();
    assert_eq!(ctl.target(), 8, "the controller starts at max_batch");

    // over-SLO, under-filled windows narrow the target to 1
    for _ in 0..3 {
        for _ in 0..4 {
            ctl.record_us(50_000.0);
            ctl.batch_done(1);
        }
    }
    assert_eq!(ctl.target(), 1, "8 → 4 → 2 → 1 across three windows");
    let (widened, narrowed) = ctl.adjustments();
    assert_eq!((widened, narrowed), (0, 3));

    // a lone request now fills the target: no hold, immediate execution
    let h = t.submit(&art, vec![0.7f32; art.input_len()], None).unwrap();
    assert_eq!(serving.spine().pump(HOST), DrainOutcome::Completed(1));
    assert!(h.wait().is_ok());
    assert_eq!(serving.spine().stats().held, 0, "narrowed target never held");
}

// ---------------------------------------------------------------------
// property: coalescing preserves the order of everything it leaves
// ---------------------------------------------------------------------

/// Random interleavings of three artifacts' requests, drained batch by
/// batch against a reference model of the queue: each drain takes the
/// front request's same-key peers (up to `max_batch`, FIFO order) and
/// every request it leaves behind keeps its relative order.  This is
/// the regression net over the single-pass extraction rewrite (the old
/// `VecDeque::remove`-in-a-scan was order-preserving but O(n²); a
/// wrong rewrite that scrambles survivors fails here).
#[test]
fn coalescing_preserves_relative_order_of_other_artifacts() {
    let wls = fixed_workloads();
    let arts_src: Vec<_> = (0..3)
        .map(|i| extract_graph(&wls[i].module, &wls[i].input_shape, &wls[i].name).unwrap())
        .collect();
    for seed in 0..5u64 {
        let serving = pump_spine(fifo(64, 2));
        let t = serving.tenant(&format!("prop-{seed}"));
        let arts: Vec<_> =
            arts_src.iter().map(|(g, b)| t.load_artifact(g, b, HOST).unwrap()).collect();
        let mut rng = XorShift::new(seed * 7 + 1);
        let n = 8 + rng.below(5);
        let mut handles = Vec::new();
        let mut inputs = Vec::new();
        let mut owners = Vec::new();
        let mut model: Vec<(usize, usize)> = Vec::new(); // (request id, artifact idx)
        for id in 0..n {
            let a = rng.below(arts.len());
            let x = rng.normal_vec(arts[a].input_len(), 0.5);
            handles.push(t.submit(&arts[a], x.clone(), None).unwrap());
            inputs.push(x);
            owners.push(a);
            model.push((id, a));
        }
        // drain to empty, checking each batch against the reference model
        while !model.is_empty() {
            let key_art = model[0].1;
            let taken: Vec<usize> = model
                .iter()
                .filter(|(_, a)| *a == key_art)
                .take(2)
                .map(|(id, _)| *id)
                .collect();
            assert_eq!(
                serving.spine().drain_one(HOST),
                taken.len(),
                "seed {seed}: batch must be the front artifact's peers"
            );
            model.retain(|(id, _)| !taken.contains(id));
            for &id in &taken {
                assert!(handles[id].is_done(), "seed {seed}: request {id} resolved");
            }
            for (id, _) in &model {
                assert!(!handles[*id].is_done(), "seed {seed}: request {id} still queued");
            }
        }
        // and everything computed the right numbers
        let mut want = Vec::new();
        for (id, h) in handles.into_iter().enumerate() {
            let out = h.wait().unwrap();
            arts[owners[id]].run_blocking(&inputs[id], &mut want).unwrap();
            assert_close(&out.output, &want, &format!("seed {seed}, request {id}"));
        }
    }
}

// ---------------------------------------------------------------------
// adaptive policy: least-loaded-queue placement
// ---------------------------------------------------------------------

/// A host-executing backend on a second device: same structural graphs
/// compile into a sibling artifact the adaptive policy may place onto.
struct TitanHost;

impl DeviceBackend for TitanHost {
    fn name(&self) -> &'static str {
        "titan-host"
    }
    fn device(&self) -> DeviceId {
        DeviceId::TitanV
    }
    fn flavor(&self) -> Flavor {
        Flavor::Ispc
    }
    fn libraries(&self) -> Vec<Library> {
        vec![Library::OpenBlas]
    }
    fn framework_slot(&self) -> DeviceType {
        DeviceType::Cuda
    }
    fn capabilities(&self) -> Capabilities {
        // host-executing: claims the arena fast path (the capability
        // gate `load_artifact` checks), unlike the default TitanV sheet
        Capabilities { arena_exec: true, ..Capabilities::for_device(DeviceId::TitanV) }
    }
}

/// A host-executing backend on the Xeon (default capabilities already
/// include the arena path).
struct XeonHost;

impl DeviceBackend for XeonHost {
    fn name(&self) -> &'static str {
        "xeon-host"
    }
    fn device(&self) -> DeviceId {
        HOST
    }
    fn flavor(&self) -> Flavor {
        Flavor::Ispc
    }
    fn libraries(&self) -> Vec<Library> {
        vec![Library::OpenBlas]
    }
    fn framework_slot(&self) -> DeviceType {
        DeviceType::Cpu
    }
}

fn two_device_serving(cfg: SpineConfig) -> ServingSession {
    let mut reg = BackendRegistry::new();
    reg.register(Box::new(XeonHost));
    reg.register(Box::new(TitanHost));
    let serving = ServingSession::over(Session::with_registry(reg), ServingConfig::default());
    serving.spine_with(cfg);
    serving
}

/// With two arena-capable devices serving the same structural graph, an
/// adaptive submit routes to the least-loaded queue (ties keep the
/// requested device); FIFO never re-routes.  `ServeOutput::device`
/// reports where the request actually ran, and both devices' artifacts
/// agree numerically.
#[test]
fn adaptive_placement_routes_to_the_least_loaded_sibling_queue() {
    let wl = &fixed_workloads()[2];
    let (g, b) = extract_graph(&wl.module, &wl.input_shape, "mlp").unwrap();

    let serving = two_device_serving(adaptive(16, 4, 0));
    let t = serving.tenant("placer");
    let xeon_art = t.load_artifact(&g, &b, HOST).unwrap();
    let titan_art = t.load_artifact(&g, &b, DeviceId::TitanV).unwrap();
    assert_ne!(xeon_art.key(), titan_art.key(), "sibling artifacts, distinct keys");

    let mut rng = XorShift::new(3);
    let x1 = rng.normal_vec(xeon_art.input_len(), 0.5);
    let x2 = rng.normal_vec(xeon_art.input_len(), 0.5);
    // empty queues tie → the requested device keeps the first request
    let h1 = t.submit(&xeon_art, x1.clone(), None).unwrap();
    assert_eq!(serving.spine().stats().placed, 0, "ties never churn");
    // now Xeon holds 1, Titan 0 → the second submit is re-placed
    let h2 = t.submit(&xeon_art, x2.clone(), None).unwrap();
    assert_eq!(serving.spine().stats().placed, 1);

    assert_eq!(serving.spine().drain_one(HOST), 1);
    assert_eq!(serving.spine().drain_one(DeviceId::TitanV), 1);
    let (o1, o2) = (h1.wait().unwrap(), h2.wait().unwrap());
    assert_eq!(o1.device, HOST);
    assert_eq!(o2.device, DeviceId::TitanV, "served by the sibling queue");

    // both placements compute the same function
    let mut want = Vec::new();
    xeon_art.run_blocking(&x1, &mut want).unwrap();
    assert_close(&o1.output, &want, "request on the requested device");
    xeon_art.run_blocking(&x2, &mut want).unwrap();
    assert_close(&o2.output, &want, "request on the placed device");

    // FIFO control: the same double submit stays on the requested queue
    let serving = two_device_serving(fifo(16, 4));
    let t = serving.tenant("fifo-placer");
    let xeon_art = t.load_artifact(&g, &b, HOST).unwrap();
    let _titan_art = t.load_artifact(&g, &b, DeviceId::TitanV).unwrap();
    let h1 = t.submit(&xeon_art, x1, None).unwrap();
    let h2 = t.submit(&xeon_art, x2, None).unwrap();
    assert_eq!(serving.spine().stats().placed, 0, "FIFO never re-places");
    assert_eq!(serving.spine().drain_one(HOST), 2, "both coalesce on the Xeon");
    assert_eq!(h1.wait().unwrap().device, HOST);
    assert_eq!(h2.wait().unwrap().device, HOST);
}

// ---------------------------------------------------------------------
// BENCH_8: the FIFO-vs-adaptive A/B smoke
// ---------------------------------------------------------------------

/// The A/B smoke runs end to end and records `BENCH_8.json` under the
/// shared schema gate, with the finite positive `p95_speedup` headline.
#[test]
fn policy_ab_smoke_writes_bench_8_json() {
    let cfg = ServeBenchConfig {
        smoke: true,
        tenants: 6,
        requests: 48,
        workers: 2,
        max_batch: 4,
        policy: SpinePolicy::Adaptive,
    };
    let r = run_policy_ab(&cfg).expect("A/B smoke");
    assert!(r.p95_speedup.is_finite() && r.p95_speedup > 0.0);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_8.json");
    write_policy_ab_json(&path, &r).unwrap();
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    validate_bench_json(&doc).expect("written BENCH_8.json validates");
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("serve-policy-ab"));
    assert_eq!(doc.get("mode").and_then(Json::as_str), Some("smoke"));
    assert!(doc.get("p95_speedup").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(doc.get("fifo_p95_us").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(doc.get("adaptive_p95_us").and_then(Json::as_f64).unwrap() > 0.0);
}

// ---------------------------------------------------------------------
// report: the policy surfaces in serving_report()
// ---------------------------------------------------------------------

/// The spine line names the active policy and the new counters.
#[test]
fn serving_report_names_the_policy_and_new_counters() {
    let serving = pump_spine(adaptive(8, 2, 0));
    let _ = serving.tenant("report");
    let report = serving.serving_report();
    assert!(report.contains("spine: 0 workers, adaptive policy"), "{report}");
    assert!(report.contains("failed"), "{report}");
    assert!(report.contains("held"), "{report}");
    assert!(report.contains("placed"), "{report}");
}
