//! Acceptance tests for the fast execution path: memory planner + arena
//! executor + optimized kernels.
//!
//! This binary installs the counting allocator, so the zero-allocation
//! claim is measured at the allocator, not inferred.  (The test harness
//! runs tests on several threads; the alloc-delta check therefore retries
//! — a single clean run proves the path itself allocates nothing, while a
//! real allocation inside `run` would taint *every* attempt.)

use sol::devsim::DeviceId;
use sol::exec::kernelbench::{
    fig3_cnn_module, run_kernel_bench, validate_bench_json, write_bench_json,
};
use sol::framework::{install_default, Tensor};
use sol::frontend::{extract_graph, ArenaExec, SolModel};
use sol::passes::OptimizeOptions;
use sol::session::{stages, Session};
use sol::util::alloc::alloc_count;
use sol::util::Json;

#[global_allocator]
static ALLOC: sol::util::alloc::CountingAllocator = sol::util::alloc::CountingAllocator;

/// Acceptance: steady-state runs on the fig3 CNN perform 0 heap
/// allocations in the kernel loop.
#[test]
fn steady_state_run_performs_zero_heap_allocations() {
    let (module, shape) = fig3_cnn_module();
    let (graph, binding) = extract_graph(&module, &shape, "fig3-cnn").unwrap();
    let exec = ArenaExec::build(&graph, &binding, 1).unwrap();
    let input = Tensor::randn(&shape, 7, 0.5).to_f32().unwrap();
    exec.run(&input).unwrap(); // cold run: counters resolve lazily nowhere, but be fair
    let mut clean = false;
    let mut deltas = Vec::new();
    for _ in 0..20 {
        let a0 = alloc_count();
        exec.run(&input).unwrap();
        let delta = alloc_count() - a0;
        deltas.push(delta);
        if delta == 0 {
            clean = true;
            break;
        }
    }
    assert!(
        clean,
        "no allocation-free steady-state run in 20 attempts (deltas {deltas:?}) — \
         the arena executor allocates on the hot path"
    );
}

/// The planned fast path and the framework's own per-op execution agree.
#[test]
fn fast_forward_matches_framework_numerics() {
    let (module, shape) = fig3_cnn_module();
    let reg = install_default();
    let x = Tensor::randn(&shape, 11, 0.5);
    let want = module.forward(&reg, &x).unwrap().to_f32().unwrap();
    let sol = SolModel::optimize(
        &module,
        &shape,
        "fig3-cnn",
        &OptimizeOptions::new(DeviceId::Xeon6126),
    )
    .unwrap();
    assert!(sol.arena_exec().is_some(), "CPU target must take the fast path");
    let got = sol.forward(&x).unwrap().to_f32().unwrap();
    assert_eq!(want.len(), got.len());
    for (i, (a, b)) in want.iter().zip(&got).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + a.abs().max(b.abs())),
            "elem {i}: {a} vs {b}"
        );
    }
}

/// Framework-side parameter mutation reaches the fast path (the §V-A
/// version-counter staleness protocol).
#[test]
fn param_mutation_invalidates_the_snapshot() {
    let (module, shape) = fig3_cnn_module();
    let reg = install_default();
    let sol = SolModel::optimize(
        &module,
        &shape,
        "fig3-cnn",
        &OptimizeOptions::new(DeviceId::Xeon6126),
    )
    .unwrap();
    let x = Tensor::randn(&shape, 13, 0.5);
    let before = sol.forward(&x).unwrap().to_f32().unwrap();
    module.parameters()[0].1.fill_(0.01).unwrap();
    let after = sol.forward(&x).unwrap().to_f32().unwrap();
    assert_ne!(before, after, "stale parameter snapshot");
    let want = module.forward(&reg, &x).unwrap().to_f32().unwrap();
    for (a, b) in want.iter().zip(&after) {
        assert!((a - b).abs() <= 1e-4 * (1.0 + a.abs().max(b.abs())), "{a} vs {b}");
    }
}

/// Regression: a mutation to a parameter whose own version counter stays
/// below the max over all parameters must still invalidate the snapshot
/// (the staleness signal is the version *sum*, not the max).
#[test]
fn low_version_param_mutation_still_invalidates() {
    let (module, shape) = fig3_cnn_module();
    let reg = install_default();
    let sol = SolModel::optimize(
        &module,
        &shape,
        "fig3-cnn",
        &OptimizeOptions::new(DeviceId::Xeon6126),
    )
    .unwrap();
    let x = Tensor::randn(&shape, 17, 0.5);
    let params = module.parameters();
    // push one tensor's version to 2, refresh via a forward...
    params[0].1.fill_(0.02).unwrap();
    params[0].1.fill_(0.03).unwrap();
    let _ = sol.forward(&x).unwrap();
    // ...then mutate a *different* tensor once: its version (1) is below
    // the max (2), so a max-based check would miss it
    params[2].1.fill_(0.04).unwrap();
    let got = sol.forward(&x).unwrap().to_f32().unwrap();
    let want = module.forward(&reg, &x).unwrap().to_f32().unwrap();
    for (a, b) in want.iter().zip(&got) {
        assert!((a - b).abs() <= 1e-4 * (1.0 + a.abs().max(b.abs())), "{a} vs {b}");
    }
}

/// Pure-simulation devices skip the planner (cheap path) but CPU compiles
/// carry a plan; the ablation toggle works by name.
#[test]
fn planner_is_device_gated_and_ablatable() {
    let session = Session::new();
    let g = sol::workloads::NetId::Squeezenet1_1.build(1);
    let cpu = session.compile(&g, DeviceId::Xeon6126);
    assert!(cpu.memory_plan.is_some(), "CPU compile must plan memory");
    let plan = cpu.memory_plan.as_ref().unwrap();
    assert!(plan.arena_bytes > 0 && plan.reuse_hits > 0);
    assert!(plan.live_peak_bytes <= plan.arena_bytes);
    let ve = session.compile(&g, DeviceId::AuroraVE10B);
    assert!(ve.memory_plan.is_none(), "pure-sim device must keep the cheap path");
    // explicit ablation: same device, no plan, distinct content address
    let mut cfg = session.pipeline_config(DeviceId::Xeon6126);
    cfg.disable_pass(stages::PLAN_MEMORY);
    let ablated = session.compile_with(&g, cfg).unwrap();
    assert!(ablated.memory_plan.is_none());
}

/// Planner metrics reach the process-global registry.
#[test]
fn arena_metrics_are_published() {
    let session = Session::new();
    let g = sol::workloads::NetId::Resnet18.build(1);
    let m = session.compile(&g, DeviceId::Xeon6126);
    let plan = m.memory_plan.as_ref().unwrap();
    assert!(sol::metrics::counter("arena.bytes_peak").get() >= plan.arena_bytes as u64);
    assert!(sol::metrics::counter("arena.slots").get() >= plan.slot_bytes.len() as u64);
    assert!(sol::metrics::counter("arena.reuse_hits").get() >= plan.reuse_hits as u64);
}

/// The smoke bench runs end to end and records the perf trajectory
/// (BENCH_4.json) with the contract fields.
#[test]
fn bench_smoke_writes_bench_4_json() {
    let rows = run_kernel_bench(true);
    assert!(rows.iter().any(|r| r.op == "conv2d_64x64.naive"));
    assert!(rows.iter().any(|r| r.op == "conv2d_64x64.fast.t1"));
    assert!(rows.iter().any(|r| r.op == "arena_exec.fig3_cnn.steady"));
    assert!(rows.iter().all(|r| r.ns_per_iter > 0.0));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_4.json");
    write_bench_json(&path, &rows, true).unwrap();
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    // the written file must satisfy the recorded-trajectory schema — a
    // stale seed (zeroed timings, dropped keys) fails here, not in CI diffs
    validate_bench_json(&doc).expect("written BENCH_4.json validates");
    assert_eq!(doc.get("mode").and_then(Json::as_str), Some("smoke"));
    assert!(doc.get("conv2d_speedup").and_then(Json::as_f64).unwrap() > 0.0);
    let rows_json = doc.get("rows").and_then(Json::as_arr).unwrap();
    assert_eq!(rows_json.len(), rows.len());
    for r in rows_json {
        for field in ["op", "bytes", "ns_per_iter", "allocs_per_run"] {
            assert!(r.get(field).is_some(), "missing {field}");
        }
        let ns = r.get("ns_per_iter").and_then(Json::as_f64).unwrap();
        assert!(ns > 0.0, "stale row with zero timing: {r:?}");
    }
}
