//! Tier-1 acceptance tests for the cross-backend consistency audit
//! (`sol::audit`): the differential sweep is clean on the shipped
//! backends, covers every registered device × capability path, reuses
//! the session's compile cache across sweeps, publishes `audit.*`
//! metrics into the serving report — and, crucially, an intentionally
//! perturbed kernel output IS caught, with a finding that names the
//! device pair and both pipeline fingerprints.

use sol::audit::{AuditConfig, AuditEngine, ExecPath, FaultSpec};
use sol::devsim::DeviceId;
use sol::session::{ServingConfig, ServingSession};

/// The full sweep (fixed workloads + a few seeds) reports zero findings
/// on the shipped backends, and its grid runs every registry device
/// through the naive path plus each capability-advertised path.
#[test]
fn full_sweep_is_clean_and_covers_every_device() {
    let engine = AuditEngine::new(AuditConfig { seeds: 4, ..AuditConfig::default() });
    let report = engine.run().expect("sweep runs");
    assert!(report.passed(), "unexpected findings:\n{}", report.summary());

    assert_eq!(report.devices, engine.session().registry().devices());
    for device in &report.devices {
        let caps = engine.session().registry().capabilities_for(*device);
        let paths: Vec<ExecPath> = report
            .grid
            .iter()
            .filter(|v| v.device == Some(*device))
            .map(|v| v.path)
            .collect();
        assert!(paths.contains(&ExecPath::Naive), "{device:?} must run naive");
        assert_eq!(paths.contains(&ExecPath::Arena), caps.arena_exec, "{device:?} arena");
        assert_eq!(paths.contains(&ExecPath::Offload), caps.offload, "{device:?} offload");
    }

    // 3 fixed workloads + 4 seeded ones, every grid slot executed
    assert_eq!(report.workloads.len(), 7);
    assert_eq!(report.skipped, 0, "no grid slot may silently skip on shipped backends");
    let runs_per_workload = report.grid.len();
    assert_eq!(report.variants, runs_per_workload * report.workloads.len());
    // all outputs (variants + the framework reference) compared pairwise
    let outputs = runs_per_workload + 1;
    assert_eq!(report.comparisons, report.workloads.len() * outputs * (outputs - 1) / 2);
}

/// The acceptance self-test: perturb one (device, path) variant's output
/// and the audit must fail, with findings that name the diverging device
/// pair and carry both real pipeline fingerprints.
#[test]
fn injected_fault_is_caught_and_findings_name_the_device_pair() {
    let fault = FaultSpec { device: DeviceId::TitanV, path: ExecPath::Offload, offset: 0.25 };
    let engine =
        AuditEngine::new(AuditConfig { seeds: 0, fault: Some(fault), ..Default::default() });
    let report = engine.run().expect("sweep runs");
    assert!(!report.passed(), "the perturbed kernel must be caught");

    let faulted = |v: &sol::audit::Variant| {
        v.device == Some(DeviceId::TitanV) && v.path == ExecPath::Offload
    };
    for f in &report.findings {
        // only the faulted variant diverges; every finding involves it
        assert!(faulted(&f.left) || faulted(&f.right), "stray finding: {f}");
        // and the drift is the injected offset, not generator noise
        assert!(f.max_abs > 0.2 && f.max_abs < 0.3, "unexpected drift in {f}");
        assert_eq!(f.worst_index, 0, "the fault hits element 0");
    }
    // the faulted device diverges from the framework reference...
    assert!(report.findings.iter().any(|f| f.left.device.is_none()));
    // ...and from a concrete second device (a device *pair*)
    let pair = report
        .findings
        .iter()
        .find(|f| f.left.device.is_some() && f.right.device.is_some())
        .expect("a device-pair finding");
    assert_ne!(pair.left.device, pair.right.device);
    // both sides carry their real (nonzero) pipeline fingerprints, and
    // the human rendering names the pair
    assert_ne!(pair.left.fingerprint, 0);
    assert_ne!(pair.right.fingerprint, 0);
    let rendered = pair.to_json().to_string();
    assert!(rendered.contains("TitanV"), "{rendered}");
    let text = pair.to_string();
    assert!(text.contains("TitanV/offload@"), "{text}");

    // the report JSON flips to fail and serializes the findings
    let json = report.to_json();
    assert_eq!(json.get("status").and_then(sol::util::Json::as_str), Some("fail"));
    assert!(!json.get("findings").and_then(sol::util::Json::as_arr).unwrap().is_empty());
}

/// Repeat sweeps over one engine hit the session's content-addressed
/// compile cache instead of recompiling the workload set.
#[test]
fn repeat_sweeps_reuse_the_compile_cache() {
    let engine = AuditEngine::new(AuditConfig { seeds: 1, ..Default::default() });
    engine.run().expect("first sweep");
    let (hits0, misses0) = (engine.session().cache().hits(), engine.session().cache().misses());
    assert!(misses0 > 0, "the first sweep compiles");
    engine.run().expect("second sweep");
    assert_eq!(engine.session().cache().misses(), misses0, "second sweep recompiles nothing");
    assert!(engine.session().cache().hits() > hits0, "second sweep is served from cache");
}

/// Audit sweeps publish cumulative `audit.*` counters, and the serving
/// report surfaces them next to the `arena.*` / `exec.*` gauges.
#[test]
fn audit_metrics_flow_into_the_serving_report() {
    let engine = AuditEngine::new(AuditConfig { seeds: 0, ..Default::default() });
    let report = engine.run().expect("sweep runs");
    assert!(sol::metrics::counter("audit.workloads").get() >= report.workloads.len() as u64);
    assert!(sol::metrics::counter("audit.variants").get() >= report.variants as u64);
    assert!(sol::metrics::counter("audit.comparisons").get() >= report.comparisons as u64);

    let serving = ServingSession::new(ServingConfig::default());
    let out = serving.serving_report();
    assert!(out.contains("audit.workloads="), "serving report must surface audit metrics:\n{out}");
    assert!(out.contains("audit.findings="), "{out}");
}
