//! Offline stub of the XLA/PJRT Rust bindings.
//!
//! The real reproduction pipeline AOT-lowers JAX/Pallas programs to HLO
//! text and executes them through a PJRT CPU client.  This container has
//! neither the XLA C++ runtime nor the artifacts, so this crate provides
//! an API-compatible surface that:
//!
//! * type-checks everything the coordinator compiles against
//!   ([`PjRtClient`], [`PjRtLoadedExecutable`], [`Literal`], ...),
//! * carries real host data through [`Literal`] (so literal round-trips
//!   work), and
//! * fails with a clear [`XlaError`] at the points that would need the
//!   native runtime (`compile`, `execute`).
//!
//! Callers already treat PJRT as optional — every integration test skips
//! when `PjRtEngine::new()` errors — so the stub degrades gracefully.

use std::fmt;
use std::path::Path;
use std::sync::Arc;

/// Error type for all fallible stub operations.
#[derive(Debug, Clone)]
pub struct XlaError {
    message: String,
}

impl XlaError {
    pub fn new(message: impl Into<String>) -> Self {
        XlaError { message: message.into() }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.message)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

const NO_RUNTIME: &str =
    "PJRT native runtime is not available in this build (offline stub)";

/// Element types a [`Literal`] can hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    I32,
}

/// Sealed-ish conversion trait for host element types.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn to_le(self) -> [u8; 4];
    fn from_le(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::I32;
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
}

/// A host-side literal: raw bytes + element type + dimensions.
#[derive(Debug, Clone)]
pub struct Literal {
    bytes: Vec<u8>,
    ty: ElementType,
    dims: Vec<i64>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le());
        }
        Literal { bytes, ty: T::TY, dims: vec![data.len() as i64], tuple: None }
    }

    /// Reinterpret under new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = (self.bytes.len() / 4) as i64;
        if want != have {
            return Err(XlaError::new(format!(
                "reshape: {have} elements cannot view as {dims:?}"
            )));
        }
        Ok(Literal {
            bytes: self.bytes.clone(),
            ty: self.ty,
            dims: dims.to_vec(),
            tuple: None,
        })
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(XlaError::new(format!(
                "to_vec: literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        self.tuple
            .clone()
            .ok_or_else(|| XlaError::new("to_tuple on a non-tuple literal"))
    }

    pub fn element_count(&self) -> usize {
        self.bytes.len() / 4
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub: records the source path only).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    /// The real binding parses HLO text; the stub only verifies the file
    /// exists so missing-artifact errors stay precise.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let p = path.as_ref();
        if !p.exists() {
            return Err(XlaError::new(format!("no HLO text file at {p:?}")));
        }
        Ok(HloModuleProto { path: p.display().to_string() })
    }
}

/// An XLA computation handle.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    #[allow(dead_code)]
    path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { path: proto.path.clone() }
    }
}

/// A device buffer handle (stub: wraps a literal).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Arc<Literal>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok((*self.literal).clone())
    }
}

/// A compiled, loaded executable.  The stub can never be constructed via
/// [`PjRtClient::compile`] (which errors), so its execute methods are
/// unreachable in practice; they error defensively anyway.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(NO_RUNTIME))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(NO_RUNTIME))
    }
}

/// The PJRT client.  `cpu()` succeeds (the stub is a valid "platform" for
/// literal plumbing); `compile` reports the missing native runtime.
#[derive(Debug)]
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "stub-cpu" })
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::new(NO_RUNTIME))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_validates_element_count() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn compile_reports_missing_runtime() {
        let c = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { path: "x".into() };
        let err = c.compile(&XlaComputation::from_proto(&proto)).unwrap_err();
        assert!(err.to_string().contains("offline stub"));
    }
}
