//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides the subset of the real `anyhow` API the workspace uses:
//!
//! * [`Error`] — an erased error value with a context chain
//! * [`Result`] — `Result<T, Error>` with a defaulted error parameter
//! * [`anyhow!`] / [`bail!`] — format-style error construction
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`s and
//!   `Option`s
//! * blanket `From<E: std::error::Error>` so `?` erases concrete errors
//!
//! Semantics match real `anyhow` where it matters to callers: `Display`
//! prints the outermost message, `Debug` prints the message plus a
//! `Caused by:` chain, and attaching context pushes a new outermost layer.

use std::error::Error as StdError;
use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An erased error: an outermost message plus the chain of causes.
pub struct Error {
    /// `chain[0]` is the outermost (most recently attached) message.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Erase a concrete `std::error::Error`, preserving its source chain.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        let mut chain = vec![error.to_string()];
        let mut src = error.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Wrap with an additional layer of context (new outermost message).
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first (mirrors `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, colon-separated (anyhow-compatible)
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// `?` on any concrete std error inside a `-> anyhow::Result<_>` function.
// (Like real anyhow, `Error` itself does not implement `std::error::Error`,
// which keeps this blanket impl coherent with `From<T> for T`.)
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

mod ext {
    /// Conversion into [`crate::Error`] used by the [`crate::Context`]
    /// blanket impl.  Mirrors real anyhow's private `ext::StdError`: the
    /// blanket over `std::error::Error` and the concrete impl for `Error`
    /// are coherent because `Error` can never implement the std trait.
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoAnyhow for E {
        fn into_anyhow(self) -> crate::Error {
            crate::Error::new(self)
        }
    }

    impl IntoAnyhow for crate::Error {
        fn into_anyhow(self) -> crate::Error {
            self
        }
    }
}

/// Attach context to errors (`anyhow::Context` subset).
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoAnyhow> Context<T, E> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| ext::IntoAnyhow::into_anyhow(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| ext::IntoAnyhow::into_anyhow(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_erases_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_layers_stack() {
        let e = io_fail().context("loading config").unwrap_err();
        assert_eq!(e.to_string(), "loading config");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn with_context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 42));
        let e = r.with_context(|| format!("outer {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "outer 7");
        assert_eq!(e.root_cause(), "inner 42");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("x too big: {x}");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert_eq!(f(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }
}
