"""AOT lowering: every ENTRIES graph -> artifacts/<name>.hlo.txt + manifest.

Interchange format is HLO *text*, NOT ``lowered.compile()`` /
``.serialize()``: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the rust side's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``).  The text parser reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

The manifest records each entry's input/output shapes+dtypes so the rust
runtime (rust/src/runtime/) can allocate literals and validate signatures
without re-deriving them from HLO.

Python runs ONCE, at build time (``make artifacts``); the rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import ENTRIES

_DTYPE_NAMES = {"float32": "f32", "int32": "i32", "bfloat16": "bf16"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust unwrap)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sig_of(aval) -> dict:
    name = _DTYPE_NAMES.get(str(aval.dtype), str(aval.dtype))
    return {"shape": list(aval.shape), "dtype": name}


def source_fingerprint() -> str:
    """Hash of the compile-path sources; embedded in the manifest so
    ``make artifacts`` can skip when nothing changed."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated entry filter")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    fp = source_fingerprint()

    if args.only is None and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("fingerprint") == fp and all(
                os.path.exists(os.path.join(args.out_dir, f"{n}.hlo.txt"))
                for n in old.get("entries", {})
            ) and set(old.get("entries", {})) == set(ENTRIES):
                print(f"artifacts up-to-date ({len(ENTRIES)} entries), skipping")
                return
        except (json.JSONDecodeError, KeyError):
            pass

    only = set(args.only.split(",")) if args.only else None
    manifest: dict = {"fingerprint": fp, "entries": {}}
    for name, (fn, specs) in sorted(ENTRIES.items()):
        if only is not None and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *specs)
        manifest["entries"][name] = {
            "inputs": [sig_of(s) for s in specs],
            "outputs": [sig_of(o) for o in out_shapes],
            "hlo_bytes": len(text),
        }
        print(f"  {name}: {len(specs)} in, {len(out_shapes)} out, {len(text)//1024} KiB hlo")

    if only is None:
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
        print(f"wrote {manifest_path} ({len(manifest['entries'])} entries)")
    else:
        print("partial build (--only): manifest not rewritten", file=sys.stderr)


if __name__ == "__main__":
    main()
