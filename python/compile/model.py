"""L2 — JAX compute graphs for the SOL reproduction, in two execution shapes.

Every workload graph exists in (up to) three variants:

* ``sol``  — what SOL's compiler produces: the DFP-fused Pallas kernels
  (kernels/*) chained into one jitted graph; one executable per network.
* ``ref``  — the stock-framework computation as one graph (used as the
  numeric oracle and for training baselines).
* per-op  — the stock framework's *execution structure*: each layer is its
  own entry point, so the rust Torchlet dispatcher can run the baseline the
  way PyTorch actually runs it — one kernel launch + dispatch per op, all
  intermediates materialized.  SOL-vs-baseline wallclock in the rust benches
  is therefore a real structural comparison, not a flag on a cost model.

``ENTRIES`` maps entry-point name -> (fn, example_args); aot.py lowers each
to ``artifacts/<name>.hlo.txt`` and records signatures in ``manifest.json``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import (
    avgpool_3x3,
    conv3x3_bias_relu_maxpool,
    depthwise3x3_bias_relu,
    linear_relu,
)
from .kernels import ref as R

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# --------------------------------------------------------------------------
# Differentiable fused conv block: DFP forward, library backward.
# Paper §III-A: forward and backward may use different implementations;
# the backward here is the jnp "vendor library" path via jax.vjp of the ref.
# --------------------------------------------------------------------------
def _make_conv_block(pool: bool):
    @jax.custom_vjp
    def cb(x, w, b):
        return conv3x3_bias_relu_maxpool(x, w, b, pool=pool)

    def fwd(x, w, b):
        return conv3x3_bias_relu_maxpool(x, w, b, pool=pool), (x, w, b)

    def bwd(res, g):
        x, w, b = res
        _, vjp = jax.vjp(
            lambda x, w, b: R.conv3x3_bias_relu_maxpool_ref(x, w, b, pool=pool),
            x, w, b,
        )
        return vjp(g)

    cb.defvjp(fwd, bwd)
    return cb


_conv_block_pool = _make_conv_block(True)
_conv_block_nopool = _make_conv_block(False)


def conv_block(x, w, b, pool=True):
    """DFP-fused conv block with a library backward (see module docstring)."""
    return (_conv_block_pool if pool else _conv_block_nopool)(x, w, b)


@jax.custom_vjp
def depthwise_block(x, w, b):
    return depthwise3x3_bias_relu(x, w, b)


def _dw_fwd(x, w, b):
    return depthwise3x3_bias_relu(x, w, b), (x, w, b)


def _dw_bwd(res, g):
    x, w, b = res
    _, vjp = jax.vjp(R.depthwise3x3_bias_relu_ref, x, w, b)
    return vjp(g)


depthwise_block.defvjp(_dw_fwd, _dw_bwd)


def pad_hw(x):
    """SAME padding for the pre-padded-input kernels (NHWC)."""
    return jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))


def softmax_xent(logits, labels):
    """Mean softmax cross-entropy with integer labels."""
    logz = jax.nn.log_softmax(logits.astype(F32), axis=-1)
    return -jnp.take_along_axis(logz, labels[:, None], axis=-1).mean()


# --------------------------------------------------------------------------
# MLP — the paper's "3-layer MLP with 8192 features and ReLU" (§VI-B).
# 8192 -> 8192 -> 8192 -> 10: ~134M parameters, the e2e training workload.
# --------------------------------------------------------------------------
MLP_IN, MLP_HID, MLP_OUT = 8192, 8192, 10
MLP_LR = 0.1


def mlp_params_spec():
    return [
        spec((MLP_IN, MLP_HID)), spec((MLP_HID,)),
        spec((MLP_HID, MLP_HID)), spec((MLP_HID,)),
        spec((MLP_HID, MLP_OUT)), spec((MLP_OUT,)),
    ]


def mlp_fwd_sol(w1, b1, w2, b2, w3, b3, x):
    h1 = linear_relu(x, w1, b1)
    h2 = linear_relu(h1, w2, b2)
    return (jnp.dot(h2, w3) + b3,)  # final layer: plain DNN-module matmul


def mlp_fwd_ref(w1, b1, w2, b2, w3, b3, x):
    h1 = R.linear_relu_ref(x, w1, b1)
    h2 = R.linear_relu_ref(h1, w2, b2)
    return (jnp.dot(h2, w3) + b3,)


def _mlp_train_step(fwd, w1, b1, w2, b2, w3, b3, x, y):
    def loss_fn(params):
        (logits,) = fwd(*params, x)
        return softmax_xent(logits, y)

    params = (w1, b1, w2, b2, w3, b3)
    loss, grads = jax.value_and_grad(loss_fn)(params)
    new = tuple(p - MLP_LR * g for p, g in zip(params, grads))
    return new + (loss,)


mlp_train_sol = functools.partial(_mlp_train_step, mlp_fwd_sol)
mlp_train_ref = functools.partial(_mlp_train_step, mlp_fwd_ref)


# --------------------------------------------------------------------------
# MiniCNN — the end-to-end CNN (quickstart / deploy): CIFAR-shaped input.
# conv3->32 +pool, conv32->64 +pool, fc 4096->256 relu, fc 256->10.
# --------------------------------------------------------------------------
CNN_H = 32


def cnn_params_spec():
    return [
        spec((3, 3, 3, 32)), spec((32,)),
        spec((3, 3, 32, 64)), spec((64,)),
        spec((CNN_H // 4 * CNN_H // 4 * 64, 256)), spec((256,)),
        spec((256, 10)), spec((10,)),
    ]


def _cnn_fwd(conv, lin, cw1, cb1, cw2, cb2, fw1, fb1, fw2, fb2, x):
    h = conv(pad_hw(x), cw1, cb1, True)          # [B, 16, 16, 32]
    h = conv(pad_hw(h), cw2, cb2, True)          # [B, 8, 8, 64]
    h = h.reshape(h.shape[0], -1)                # [B, 4096]
    h = lin(h, fw1, fb1)                         # [B, 256]
    return (jnp.dot(h, fw2) + fb2,)              # [B, 10]


def cnn_fwd_sol(*args):
    return _cnn_fwd(conv_block, linear_relu, *args)


def cnn_fwd_ref(*args):
    return _cnn_fwd(
        lambda x, w, b, p: R.conv3x3_bias_relu_maxpool_ref(x, w, b, pool=p),
        R.linear_relu_ref,
        *args,
    )


CNN_LR = 0.05


def _cnn_train_step(fwd, *args):
    *params, x, y = args
    params = tuple(params)

    def loss_fn(params):
        (logits,) = fwd(*params, x)
        return softmax_xent(logits, y)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new = tuple(p - CNN_LR * g for p, g in zip(params, grads))
    return new + (loss,)


cnn_train_sol = functools.partial(_cnn_train_step, cnn_fwd_sol)
cnn_train_ref = functools.partial(_cnn_train_step, cnn_fwd_ref)


# --------------------------------------------------------------------------
# Calibration blocks: the unit graphs the rust devsim anchors its per-device
# efficiency factors on (DESIGN.md §4), plus standalone DFP kernels.
# --------------------------------------------------------------------------
CB_C, CB_H = 64, 56  # conv-block site: 64ch, 56x56 (ResNet stage-2 shape)
DW_C, DW_H = 128, 56  # depthwise site (MobileNet/MNasNet shape)
AP_C, AP_H = 512, 128  # Listing-3 AveragePooling shape


def conv_site_sol(x, w, b):
    return (conv_block(x, w, b, pool=True),)


def conv_site_ref(x, w, b):
    return (R.conv3x3_bias_relu_maxpool_ref(x, w, b, pool=True),)


def dw_site_sol(x, w, b):
    return (depthwise3x3_bias_relu(x, w, b),)


def dw_site_ref(x, w, b):
    return (R.depthwise3x3_bias_relu_ref(x, w, b),)


def avgpool_sol(x):
    return (avgpool_3x3(x),)


def avgpool_ref(x):
    return (R.avgpool_3x3_ref(x),)


# --------------------------------------------------------------------------
# Per-op entry points — the baseline framework's execution structure.
# Rust's Torchlet dispatcher runs these one at a time, like PyTorch ops.
# --------------------------------------------------------------------------
def op_conv3x3(x, w):
    return (R.conv3x3_ref(x, w),)


def op_bias_relu(y, b):
    return (R.bias_relu_ref(y, b),)


def op_maxpool(y):
    return (R.maxpool2x2_ref(y),)


def op_linear(x, w, b):
    return (jnp.dot(x, w) + b,)


def op_relu(x):
    return (jnp.maximum(x, 0.0),)


def op_pad(x):
    return (pad_hw(x),)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------
ENTRIES: dict[str, tuple[Callable, list]] = {}


def entry(name: str, fn: Callable, args: list) -> None:
    assert name not in ENTRIES, f"duplicate entry {name}"
    ENTRIES[name] = (fn, args)


def _register_all() -> None:
    # MLP (paper's MLP workload; inference B=1, training B=64 per §VI-D)
    ps = mlp_params_spec()
    for b in (1, 64):
        entry(f"mlp_infer_sol_b{b}", mlp_fwd_sol, ps + [spec((b, MLP_IN))])
        entry(f"mlp_infer_ref_b{b}", mlp_fwd_ref, ps + [spec((b, MLP_IN))])
    for b in (16, 64):
        targs = ps + [spec((b, MLP_IN)), spec((b,), I32)]
        entry(f"mlp_train_sol_b{b}", mlp_train_sol, targs)
        entry(f"mlp_train_ref_b{b}", mlp_train_ref, targs)

    # MiniCNN (e2e example + deploy)
    cs = cnn_params_spec()
    for b in (1, 32):
        entry(f"cnn_infer_sol_b{b}", cnn_fwd_sol, cs + [spec((b, CNN_H, CNN_H, 3))])
        entry(f"cnn_infer_ref_b{b}", cnn_fwd_ref, cs + [spec((b, CNN_H, CNN_H, 3))])
    targs = cs + [spec((32, CNN_H, CNN_H, 3)), spec((32,), I32)]
    entry("cnn_train_sol_b32", cnn_train_sol, targs)
    entry("cnn_train_ref_b32", cnn_train_ref, targs)

    # Calibration sites (fused vs unfused), B=1 and B=16 (paper's batch sizes)
    for b in (1, 16):
        cargs = [
            spec((b, CB_H + 2, CB_H + 2, CB_C)),
            spec((3, 3, CB_C, CB_C)),
            spec((CB_C,)),
        ]
        entry(f"conv_site_sol_b{b}", conv_site_sol, cargs)
        entry(f"conv_site_ref_b{b}", conv_site_ref, cargs)
        dargs = [
            spec((b, DW_H + 2, DW_H + 2, DW_C)),
            spec((3, 3, DW_C)),
            spec((DW_C,)),
        ]
        entry(f"dw_site_sol_b{b}", dw_site_sol, dargs)
        entry(f"dw_site_ref_b{b}", dw_site_ref, dargs)

    # Listing-3 AveragePooling
    ap = [spec((AP_C, AP_H + 2, AP_H + 2))]
    entry("avgpool_sol", avgpool_sol, ap)
    entry("avgpool_ref", avgpool_ref, ap)

    # Per-op baseline kernels for the conv calibration site
    for b in (1, 16):
        entry(
            f"op_conv3x3_cb_b{b}",
            op_conv3x3,
            [spec((b, CB_H + 2, CB_H + 2, CB_C)), spec((3, 3, CB_C, CB_C))],
        )
        entry(
            f"op_bias_relu_cb_b{b}",
            op_bias_relu,
            [spec((b, CB_H, CB_H, CB_C)), spec((CB_C,))],
        )
        entry(f"op_maxpool_cb_b{b}", op_maxpool, [spec((b, CB_H, CB_H, CB_C))])

    # Per-op baseline kernels for the MLP (linear / relu per layer)
    for b in (1, 64):
        entry(
            f"op_linear_mlp1_b{b}",
            op_linear,
            [spec((b, MLP_IN)), spec((MLP_IN, MLP_HID)), spec((MLP_HID,))],
        )
        entry(
            f"op_linear_mlp3_b{b}",
            op_linear,
            [spec((b, MLP_HID)), spec((MLP_HID, MLP_OUT)), spec((MLP_OUT,))],
        )
        entry(f"op_relu_mlp_b{b}", op_relu, [spec((b, MLP_HID))])


_register_all()
