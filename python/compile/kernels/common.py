"""Shared tiling helpers for the DFP Pallas kernels.

The DFP module's codegen decisions (paper §III-A / §IV) boil down to: pick a
tile (block) shape that (a) fits the per-core scratchpad (VMEM on TPU,
L1/L2 on CPU, shared-mem on GPU), (b) keeps the innermost dimensions aligned
to the SIMD width, and (c) minimizes the number of nested loops.  These
helpers centralize that choice so every kernel tiles consistently.
"""

from __future__ import annotations

# TPU-shaped alignment targets (see DESIGN.md §Hardware-Adaptation):
# the VPU operates on (8, 128) lanes, the MXU on 128x128 systolic tiles.
LANE = 128
SUBLANE = 8
# Per-core VMEM budget we tile for (bytes).  Real TPUv4 has 16 MiB; we leave
# headroom for double-buffering.
VMEM_BUDGET = 8 * 1024 * 1024


def cdiv(a: int, b: int) -> int:
    """Ceiling division."""
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    """Round ``a`` up to the next multiple of ``b``."""
    return cdiv(a, b) * b


def largest_divisor_tile(dim: int, max_tile: int) -> int:
    """Largest divisor of ``dim`` that is <= ``max_tile``.

    Pallas blocks must evenly divide the array in interpret mode for the
    shapes we use, so the DFP tiler only picks exact divisors.
    """
    t = min(dim, max_tile)
    while dim % t != 0:
        t -= 1
    return t


def channel_tile(channels: int, bytes_per_elem: int, spatial: int) -> int:
    """Pick a channel-block size so ``spatial * tile`` fits the VMEM budget.

    Mirrors the DFP module's "use knowledge of vector lengths to ensure
    vector instructions are not underutilized" (paper §IV-C): prefer
    LANE-aligned tiles, fall back to exact divisors for small channel counts.
    """
    budget_elems = VMEM_BUDGET // (2 * bytes_per_elem)  # in + out buffers
    max_tile = max(1, budget_elems // max(spatial, 1))
    if channels % LANE == 0 and LANE <= max_tile:
        # Largest LANE multiple that divides channels and fits.
        t = (max_tile // LANE) * LANE
        while t >= LANE:
            if channels % t == 0:
                return t
            t -= LANE
        return LANE
    return largest_divisor_tile(channels, max_tile)
