"""Fused Conv3x3 -> Bias -> ReLU -> MaxPool2x2 DFP kernel.

This is the depth-first-parallelism showcase: the whole chain executes per
tile inside VMEM, so the conv output never round-trips to HBM before the
pooling consumes it.  The stock framework baseline (ref.py) materializes
every intermediate — that traffic difference is exactly the effect the
paper's Fig. 3 speedups come from.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the 3x3 spatial taps are
unrolled (as in Listing 3) and each tap is a [N*H*W, Cin] x [Cin, Cout_tile]
matmul feeding the MXU, instead of the per-lane FMA loops the CUDA/ISPC
backends emit.  The grid runs over out-channel tiles — the paper's CUDA
"SIMD-group" trick (independent warps on independent data) maps to
independent grid cells.  (Perf iteration log, EXPERIMENTS.md §Perf: the
batch dim moved from the grid into the block so interpret-mode lowering
emits one large dot per tap instead of per-image slices.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import LANE, largest_divisor_tile


def _conv_fused_kernel(x_ref, w_ref, b_ref, o_ref, *, pool: bool):
    """Block body over one cout-tile grid cell.

    x_ref: [N, H+2, W+2, Cin]   (pre-padded input, full batch)
    w_ref: [3, 3, Cin, TCo]
    b_ref: [TCo]
    o_ref: [N, H/2, W/2, TCo] when pool else [N, H, W, TCo]
    """
    n, hp, wp, cin = x_ref.shape
    h, w = hp - 2, wp - 2
    tco = o_ref.shape[3]
    acc = jnp.zeros((n * h * w, tco), dtype=jnp.float32)
    # Unrolled 3x3 taps: each tap is an MXU matmul over the channel dim.
    for k1 in range(3):
        for k2 in range(3):
            patch = x_ref[:, k1 : k1 + h, k2 : k2 + w, :].reshape(n * h * w, cin)
            acc = acc + jnp.dot(
                patch.astype(jnp.float32),
                w_ref[k1, k2].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
    # Bias + ReLU, still in VMEM.
    y = jnp.maximum(acc + b_ref[...].astype(jnp.float32), 0.0)
    y = y.reshape(n, h, w, tco)
    if pool:
        # MaxPool 2x2/2: the ReLU<->MaxPool elision (paper §III-A) already
        # holds — max(relu(x)) == relu(max(x)) — so fusing them is exact.
        y = y.reshape(n, h // 2, 2, w // 2, 2, tco).max(axis=(2, 4))
    o_ref[...] = y.astype(o_ref.dtype)


def conv3x3_bias_relu_maxpool(
    x: jax.Array, w: jax.Array, b: jax.Array, *, pool: bool = True
) -> jax.Array:
    """Fused conv3x3(valid, on pre-padded NHWC input) + bias + ReLU [+ maxpool2x2].

    x: [N, H+2, W+2, Cin], w: [3, 3, Cin, Cout], b: [Cout].
    Returns [N, H/2, W/2, Cout] (pool) or [N, H, W, Cout].
    """
    n, hp, wp, cin = x.shape
    h, wd = hp - 2, wp - 2
    cout = w.shape[3]
    if pool:
        assert h % 2 == 0 and wd % 2 == 0, "pooled extent must be even"
    tco = largest_divisor_tile(cout, LANE)
    oh, ow = (h // 2, wd // 2) if pool else (h, wd)
    kernel = functools.partial(_conv_fused_kernel, pool=pool)
    return pl.pallas_call(
        kernel,
        grid=(cout // tco,),
        in_specs=[
            pl.BlockSpec((n, hp, wp, cin), lambda j: (0, 0, 0, 0)),
            pl.BlockSpec((3, 3, cin, tco), lambda j: (0, 0, 0, j)),
            pl.BlockSpec((tco,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((n, oh, ow, tco), lambda j: (0, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, cout), x.dtype),
        interpret=True,
    )(x, w, b)
