"""Linear (+ReLU) DFP/DNN kernels, with split forward/backward implementations.

Paper §III-A: "SOL can mix the usage of different implementations, algorithms
and layouts between forward and backward pass".  We reproduce that design
point literally: ``linear_relu`` is a ``jax.custom_vjp`` whose forward is the
fused Pallas kernel (bias + ReLU folded into the matmul epilogue — the DFP
path) and whose backward is built from the plain tiled-matmul kernel (the
DNN/library path), with the transposed-weight layout the backward pass
prefers (§III-A's per-pass layout choice).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import LANE, largest_divisor_tile


# Tile size for the M/N grid dims.  On a real TPU this would be bounded by
# VMEM (128..512); under interpret-mode lowering every grid cell becomes a
# dynamic-slice + dot + dynamic-update-slice in the XLA loop, so the AOT
# artifacts use the largest tile that divides the dim — one cell per layer,
# letting XLA CPU see a single large dot (its own blocking is better).
# Iteration log in EXPERIMENTS.md §Perf: 128 -> 512 -> 8192.
MM_TILE = 8192


def _mm_tile(m: int) -> int:
    return largest_divisor_tile(m, MM_TILE)


def _linear_relu_kernel(x_ref, w_ref, b_ref, o_ref):
    """o = relu(x @ w + b) over one (M-tile, N-tile) grid cell."""
    y = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = jnp.maximum(y + b_ref[...].astype(jnp.float32), 0.0).astype(
        o_ref.dtype
    )


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def matmul_tiled(a: jax.Array, b: jax.Array) -> jax.Array:
    """Tiled [M,K] @ [K,N] matmul; grid over MXU-aligned (M, N) tiles."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    tm, tn = _mm_tile(m), _mm_tile(n)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


def _linear_relu_fwd_impl(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    m, k = x.shape
    n = w.shape[1]
    tm, tn = _mm_tile(m), _mm_tile(n)
    return pl.pallas_call(
        _linear_relu_kernel,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)


@jax.custom_vjp
def linear_relu(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """relu(x @ w + b) — DFP-fused forward, library-style backward."""
    return _linear_relu_fwd_impl(x, w, b)


def _linear_relu_vjp_fwd(x, w, b):
    y = _linear_relu_fwd_impl(x, w, b)
    return y, (x, w, y)


def _linear_relu_vjp_bwd(res, g):
    x, w, y = res
    # ReLU mask comes from the saved activation (cheaper than saving pre-acts).
    gm = (g * (y > 0).astype(g.dtype)).astype(g.dtype)
    # Backward uses the transposed-weight layout (paper: untransposed weights
    # are faster forward on CPU, transposed on Aurora — per-pass choice).
    dx = matmul_tiled(gm, w.T)
    dw = matmul_tiled(x.T, gm)
    db = gm.sum(axis=0)
    return dx, dw, db


linear_relu.defvjp(_linear_relu_vjp_fwd, _linear_relu_vjp_bwd)
