"""Depthwise Conv3x3 + Bias + ReLU DFP kernel ("WeightedPooling").

Paper §III-A: grouped convolutions with groups == output channels (MobileNet,
MNasNet, ShuffleNet) are NOT sent to the DNN/vendor-library module — they
boil down to a WeightedPooling layer, which the DFP module handles with the
same depth-first loop structure as AveragePooling (Listing 3), just with a
learned per-tap weight.  This kernel is that WeightedPooling.

No MXU work here — it is pure VPU (elementwise FMA over the channel lanes),
which is also why the paper found VEDNN's hand-written grouped conv beats
SOL's generated code on the SX-Aurora (§VI-D): there is no matmul to win on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import channel_tile


def _depthwise_kernel(x_ref, w_ref, b_ref, o_ref):
    """x_ref: [1, H+2, W+2, TC], w_ref: [3, 3, TC], b_ref: [TC], o_ref: [1, H, W, TC]."""
    h, w = o_ref.shape[1], o_ref.shape[2]
    acc = jnp.zeros(o_ref.shape[1:], dtype=jnp.float32)
    for k1 in range(3):
        for k2 in range(3):
            acc = acc + x_ref[0, k1 : k1 + h, k2 : k2 + w, :].astype(
                jnp.float32
            ) * w_ref[k1, k2].astype(jnp.float32)
    o_ref[0] = jnp.maximum(acc + b_ref[...].astype(jnp.float32), 0.0).astype(
        o_ref.dtype
    )


def depthwise3x3_bias_relu(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fused depthwise conv3x3 (valid, pre-padded NHWC) + bias + ReLU.

    x: [N, H+2, W+2, C], w: [3, 3, C], b: [C].  Returns [N, H, W, C].
    """
    n, hp, wp, c = x.shape
    h, wd = hp - 2, wp - 2
    tc = channel_tile(c, x.dtype.itemsize, spatial=hp * wp)
    return pl.pallas_call(
        _depthwise_kernel,
        grid=(n, c // tc),
        in_specs=[
            pl.BlockSpec((1, hp, wp, tc), lambda i, j: (i, 0, 0, j)),
            pl.BlockSpec((3, 3, tc), lambda i, j: (0, 0, j)),
            pl.BlockSpec((tc,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, h, wd, tc), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, h, wd, c), x.dtype),
        interpret=True,
    )(x, w, b)
