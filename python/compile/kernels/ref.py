"""Pure-jnp oracles for every DFP kernel — the correctness contract.

These are the "reference implementations within the AI frameworks" the paper
benchmarks against: per-layer, unfused, every intermediate materialized.
pytest asserts kernel-vs-ref allclose; the L2 baseline graphs (model.py) are
also built from these, so baseline-vs-SOL in the rust benches compares two
*numerically identical* computations with different execution structure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def avgpool_3x3_ref(x: jax.Array, *, kh: int = 3, kw: int = 3) -> jax.Array:
    """[C, H+kh-1, W+kw-1] -> [C, H, W]; divisor kh*kw (count_include_pad)."""
    c, hp, wp = x.shape
    oh, ow = hp - kh + 1, wp - kw + 1
    acc = jnp.zeros((c, oh, ow), dtype=jnp.float32)
    for k1 in range(kh):
        for k2 in range(kw):
            acc = acc + x[:, k1 : k1 + oh, k2 : k2 + ow].astype(jnp.float32)
    return (acc / (kh * kw)).astype(x.dtype)


def conv3x3_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Valid conv over pre-padded NHWC input; w: [3, 3, Cin, Cout]."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(x.dtype)


def bias_relu_ref(y: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.maximum(y + b.astype(y.dtype), 0.0)


def maxpool2x2_ref(y: jax.Array) -> jax.Array:
    n, h, w, c = y.shape
    return y.reshape(n, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def conv3x3_bias_relu_maxpool_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, pool: bool = True
) -> jax.Array:
    """The unfused baseline chain: conv -> bias -> relu [-> maxpool]."""
    y = bias_relu_ref(conv3x3_ref(x, w), b)
    return maxpool2x2_ref(y) if pool else y


def depthwise3x3_bias_relu_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise (groups == channels) conv3x3 + bias + relu, NHWC."""
    n, hp, wp, c = x.shape
    h, wd = hp - 2, wp - 2
    acc = jnp.zeros((n, h, wd, c), dtype=jnp.float32)
    for k1 in range(3):
        for k2 in range(3):
            acc = acc + x[:, k1 : k1 + h, k2 : k2 + wd, :].astype(
                jnp.float32
            ) * w[k1, k2].astype(jnp.float32)
    return jnp.maximum(acc + b.astype(jnp.float32), 0.0).astype(x.dtype)


def linear_relu_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.maximum(
        jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32),
        0.0,
    ).astype(x.dtype)


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)
