"""L1 — Pallas kernels implementing SOL's DFP (depth-first parallelism) module.

Each kernel fuses a depth-first chain of layers (conv/bias/ReLU/pool, ...) so
intermediates never leave VMEM — the Pallas analog of the paper's generated
ISPC/CUDA/NCC loop nests (Listing 3).  All kernels run with ``interpret=True``
so they lower to plain HLO ops executable by the rust PJRT CPU client.
"""

from .avgpool import avgpool_3x3
from .conv_fused import conv3x3_bias_relu_maxpool
from .depthwise import depthwise3x3_bias_relu
from .linear import linear_relu, matmul_tiled

__all__ = [
    "avgpool_3x3",
    "conv3x3_bias_relu_maxpool",
    "depthwise3x3_bias_relu",
    "linear_relu",
    "matmul_tiled",
]
