"""AveragePooling DFP kernel — the paper's Listing 3, as Pallas.

The paper shows one DFP layer description lowered to four backends (standard
C++, ISPC, CUDA, NCC).  All four share the same structure: an outer parallel
loop over channel blocks (taskIndex / blockIdx.x / omp parallel for) and a
vectorized inner loop over the output pixels with an unrolled 3x3 reduction.

Here the outer channel loop is the Pallas *grid*, the pixel loops are the
vectorized block body, and the HBM->VMEM movement the CUDA/NCC versions do
implicitly through caches is explicit in the BlockSpec.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import channel_tile


def _avgpool_kernel(x_ref, o_ref, *, kh: int, kw: int, inv_area: float):
    """Block body: out[c, p1, p0] = mean_{k1,k2} in[c, p1+k1, p0+k2]."""
    _, oh, ow = o_ref.shape
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    # Unrolled k1/k2 loops, exactly like the generated code in Listing 3.
    for k1 in range(kh):
        for k2 in range(kw):
            acc = acc + x_ref[:, k1 : k1 + oh, k2 : k2 + ow].astype(jnp.float32)
    o_ref[...] = (acc * inv_area).astype(o_ref.dtype)


def avgpool_3x3(x: jax.Array, *, kh: int = 3, kw: int = 3) -> jax.Array:
    """3x3 stride-1 average pooling over a pre-padded [C, H+kh-1, W+kw-1] input.

    ``count_include_pad=True`` semantics: the divisor is always kh*kw (the
    paper's ``K.area(p->isCountPadding())``).
    """
    c, hp, wp = x.shape
    oh, ow = hp - kh + 1, wp - kw + 1
    tc = channel_tile(c, x.dtype.itemsize, spatial=hp * wp)
    kernel = functools.partial(
        _avgpool_kernel, kh=kh, kw=kw, inv_area=1.0 / float(kh * kw)
    )
    return pl.pallas_call(
        kernel,
        grid=(c // tc,),
        in_specs=[pl.BlockSpec((tc, hp, wp), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tc, oh, ow), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, oh, ow), x.dtype),
        interpret=True,
    )(x)
