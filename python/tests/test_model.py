"""L2 graph equivalence: sol (DFP-fused) vs ref (stock) variants, and
training-step semantics (loss decreases, params update)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

from .conftest import assert_close, rand


def _cnn_params(seed=0, scale=0.1):
    return [jnp.asarray(rand(seed + i, s.shape, scale=scale)) for i, s in enumerate(M.cnn_params_spec())]


def _mlp_params(seed=0, scale=0.02):
    return [jnp.asarray(rand(seed + i, s.shape, scale=scale)) for i, s in enumerate(M.mlp_params_spec())]


class TestCnn:
    def test_fwd_sol_matches_ref(self):
        params = _cnn_params()
        x = jnp.asarray(rand(99, (2, M.CNN_H, M.CNN_H, 3)))
        (sol,) = M.cnn_fwd_sol(*params, x)
        (ref,) = M.cnn_fwd_ref(*params, x)
        assert sol.shape == (2, 10)
        assert_close(sol, ref, rtol=1e-3, atol=1e-4)

    def test_train_step_sol_matches_ref(self):
        params = _cnn_params(1)
        x = jnp.asarray(rand(50, (4, M.CNN_H, M.CNN_H, 3)))
        y = jnp.asarray(np.arange(4, dtype=np.int32) % 10)
        out_s = M.cnn_train_sol(*params, x, y)
        out_r = M.cnn_train_ref(*params, x, y)
        for s, r in zip(out_s, out_r):
            assert_close(s, r, rtol=5e-3, atol=1e-4)

    def test_loss_decreases(self):
        params = _cnn_params(2)
        x = jnp.asarray(rand(51, (8, M.CNN_H, M.CNN_H, 3)))
        y = jnp.asarray((np.arange(8) % 10).astype(np.int32))
        losses = []
        for _ in range(5):
            *params, loss = M.cnn_train_sol(*params, x, y)
            losses.append(float(loss))
        assert losses[-1] < losses[0], f"no learning: {losses}"

    def test_params_change(self):
        params = _cnn_params(3)
        x = jnp.asarray(rand(52, (2, M.CNN_H, M.CNN_H, 3)))
        y = jnp.asarray(np.zeros(2, np.int32))
        out = M.cnn_train_sol(*params, x, y)
        assert not np.allclose(np.asarray(out[0]), np.asarray(params[0]))


class TestMlpSmall:
    """MLP math checked at reduced width (same code path, manageable size)."""

    def test_fwd_variants_agree(self, monkeypatch):
        w1, b1 = rand(1, (64, 64), scale=0.1), rand(2, (64,), scale=0.1)
        w2, b2 = rand(3, (64, 64), scale=0.1), rand(4, (64,), scale=0.1)
        w3, b3 = rand(5, (64, 10), scale=0.1), rand(6, (10,), scale=0.1)
        x = rand(7, (8, 64))
        (sol,) = M.mlp_fwd_sol(w1, b1, w2, b2, w3, b3, x)
        (ref,) = M.mlp_fwd_ref(w1, b1, w2, b2, w3, b3, x)
        assert_close(sol, ref, rtol=1e-3, atol=1e-4)

    def test_train_step_variants_agree(self):
        args = [
            rand(1, (64, 64), scale=0.1), rand(2, (64,), scale=0.1),
            rand(3, (64, 64), scale=0.1), rand(4, (64,), scale=0.1),
            rand(5, (64, 10), scale=0.1), rand(6, (10,), scale=0.1),
            rand(7, (16, 64)), (np.arange(16) % 10).astype(np.int32),
        ]
        out_s = M.mlp_train_sol(*map(jnp.asarray, args))
        out_r = M.mlp_train_ref(*map(jnp.asarray, args))
        for s, r in zip(out_s, out_r):
            assert_close(s, r, rtol=5e-3, atol=1e-4)


class TestLoss:
    def test_softmax_xent_uniform(self):
        logits = jnp.zeros((4, 10))
        y = jnp.asarray(np.arange(4, dtype=np.int32))
        assert float(M.softmax_xent(logits, y)) == pytest.approx(np.log(10), rel=1e-5)

    def test_softmax_xent_confident(self):
        logits = jnp.asarray(np.eye(4, 10, dtype=np.float32) * 100.0)
        y = jnp.asarray(np.arange(4, dtype=np.int32))
        assert float(M.softmax_xent(logits, y)) == pytest.approx(0.0, abs=1e-5)


class TestRegistry:
    def test_entry_count_and_naming(self):
        assert len(M.ENTRIES) >= 30
        for name in M.ENTRIES:
            assert any(
                name.startswith(p)
                for p in ("mlp_", "cnn_", "conv_site_", "dw_site_", "avgpool_", "op_")
            ), name

    def test_every_sol_entry_has_ref_twin(self):
        for name in M.ENTRIES:
            if "_sol" in name:
                assert name.replace("_sol", "_ref") in M.ENTRIES, name

    def test_specs_are_static(self):
        for name, (_, specs) in M.ENTRIES.items():
            for s in specs:
                assert all(isinstance(d, int) for d in s.shape), name
