"""Depthwise ("WeightedPooling") DFP kernel vs oracle (paper §III-A)."""

import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import depthwise3x3_bias_relu
from compile.kernels.ref import depthwise3x3_bias_relu_ref

from .conftest import assert_close, rand


def _mk(seed, n, hw, c):
    return (
        rand(seed, (n, hw + 2, hw + 2, c)),
        rand(seed + 1, (3, 3, c)),
        rand(seed + 2, (c,)),
    )


@given(
    n=st.integers(1, 3),
    hw=st.sampled_from([1, 4, 7, 12]),
    c=st.sampled_from([1, 2, 8, 32, 128]),
    seed=st.integers(0, 2**16),
)
def test_matches_ref(n, hw, c, seed):
    x, w, b = _mk(seed, n, hw, c)
    assert_close(
        depthwise3x3_bias_relu(x, w, b),
        depthwise3x3_bias_relu_ref(x, w, b),
        rtol=1e-4, atol=1e-5,
    )


def test_is_weighted_pooling():
    """With uniform weights 1/9 and zero bias this IS 3x3 average pooling —
    the paper's observation that groups==channels convs reduce to pooling."""
    from compile.kernels import avgpool_3x3

    x = rand(3, (1, 10, 10, 8))
    w = np.full((3, 3, 8), 1.0 / 9.0, np.float32)
    b = np.zeros((8,), np.float32)
    dw = np.asarray(depthwise3x3_bias_relu(x, w, b))
    # avgpool kernel works in [C, H, W]; relu(avg) == weighted-pool w/ relu
    ap = np.asarray(avgpool_3x3(np.transpose(x[0], (2, 0, 1))))
    ap = np.maximum(np.transpose(ap, (1, 2, 0)), 0.0)
    np.testing.assert_allclose(dw[0], ap, rtol=1e-5, atol=1e-6)


def test_channels_independent():
    """Depthwise must not mix channels: zeroing one channel's weights zeroes
    exactly that output channel (given zero bias)."""
    x, w, b = _mk(5, 1, 6, 4)
    b = np.zeros_like(b)
    w[:, :, 2] = 0.0
    out = np.asarray(depthwise3x3_bias_relu(x, w, b))
    assert (out[..., 2] == 0).all()
    assert (out[..., 0] != 0).any()
