"""Linear(+ReLU) kernels: fused forward, split-implementation backward."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import linear_relu, matmul_tiled
from compile.kernels.ref import linear_relu_ref, matmul_ref

from .conftest import assert_close, rand


@given(
    m=st.sampled_from([1, 3, 16, 64]),
    k=st.sampled_from([8, 32, 100]),
    n=st.sampled_from([4, 10, 128, 256]),
    seed=st.integers(0, 2**16),
)
def test_linear_relu_matches_ref(m, k, n, seed):
    x, w, b = rand(seed, (m, k)), rand(seed + 1, (k, n)), rand(seed + 2, (n,))
    assert_close(linear_relu(x, w, b), linear_relu_ref(x, w, b), rtol=1e-3)


@given(
    m=st.sampled_from([1, 7, 32]),
    k=st.sampled_from([16, 64]),
    n=st.sampled_from([8, 96]),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    a, b = rand(seed, (m, k)), rand(seed + 1, (k, n))
    assert_close(matmul_tiled(a, b), matmul_ref(a, b), rtol=1e-3)


def test_vjp_matches_ref_vjp():
    """The custom (DFP-fwd / library-bwd) vjp must equal autodiff of the ref."""
    x, w, b = rand(1, (8, 32)), rand(2, (32, 16)), rand(3, (16,))
    g = rand(4, (8, 16))

    def run(fn):
        out, vjp = jax.vjp(fn, jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
        return out, vjp(jnp.asarray(g))

    out_k, (dx_k, dw_k, db_k) = run(linear_relu)
    out_r, (dx_r, dw_r, db_r) = run(linear_relu_ref)
    assert_close(out_k, out_r, rtol=1e-3)
    assert_close(dx_k, dx_r, rtol=1e-3)
    assert_close(dw_k, dw_r, rtol=1e-3)
    assert_close(db_k, db_r, rtol=1e-3)


def test_vjp_relu_mask():
    """Gradient must be zero wherever the forward ReLU clamped."""
    x = np.array([[1.0, -1.0]], np.float32)
    w = np.eye(2, dtype=np.float32)
    b = np.zeros((2,), np.float32)
    dx = jax.grad(lambda x: linear_relu(x, w, b).sum())(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(dx), [[1.0, 0.0]])


def test_grad_through_chain():
    """Two stacked linear_relu layers differentiate like the ref chain."""
    x = rand(5, (4, 16))
    w1, b1 = rand(6, (16, 32)), rand(7, (32,))
    w2, b2 = rand(8, (32, 8)), rand(9, (8,))

    def loss_k(x):
        return linear_relu(linear_relu(x, w1, b1), w2, b2).sum()

    def loss_r(x):
        return linear_relu_ref(linear_relu_ref(x, w1, b1), w2, b2).sum()

    assert_close(jax.grad(loss_k)(jnp.asarray(x)), jax.grad(loss_r)(jnp.asarray(x)), rtol=1e-3)
