"""Fused conv3x3+bias+ReLU+maxpool DFP kernel vs the unfused oracle chain."""

import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import conv3x3_bias_relu_maxpool
from compile.kernels.ref import conv3x3_bias_relu_maxpool_ref

from .conftest import assert_close, rand


def _mk(seed, n, h, w, cin, cout, scale=0.2):
    return (
        rand(seed, (n, h + 2, w + 2, cin), scale=scale),
        rand(seed + 1, (3, 3, cin, cout), scale=scale),
        rand(seed + 2, (cout,), scale=scale),
    )


@given(
    n=st.integers(1, 3),
    hw=st.sampled_from([4, 6, 8, 10]),
    cin=st.sampled_from([1, 3, 8, 17]),
    cout=st.sampled_from([4, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_matches_ref_pool(n, hw, cin, cout, seed):
    x, w, b = _mk(seed, n, hw, hw, cin, cout)
    assert_close(
        conv3x3_bias_relu_maxpool(x, w, b, pool=True),
        conv3x3_bias_relu_maxpool_ref(x, w, b, pool=True),
        rtol=1e-3, atol=1e-4,
    )


@given(
    hw=st.sampled_from([3, 5, 8]),  # no-pool allows odd extents
    cout=st.sampled_from([2, 8, 128]),
    seed=st.integers(0, 2**16),
)
def test_matches_ref_nopool(hw, cout, seed):
    x, w, b = _mk(seed, 1, hw, hw, 4, cout)
    assert_close(
        conv3x3_bias_relu_maxpool(x, w, b, pool=False),
        conv3x3_bias_relu_maxpool_ref(x, w, b, pool=False),
        rtol=1e-3, atol=1e-4,
    )


def test_relu_clamps_negative():
    """All-negative bias drives pre-acts negative -> output must be all zero."""
    x, w, _ = _mk(7, 1, 4, 4, 2, 4, scale=0.01)
    b = np.full((4,), -10.0, np.float32)
    out = np.asarray(conv3x3_bias_relu_maxpool(x, w, b))
    assert (out == 0).all()


def test_relu_maxpool_commute():
    """The §III-A elision identity the fusion relies on: max∘relu == relu∘max."""
    x, w, b = _mk(11, 2, 8, 8, 3, 8)
    fused = conv3x3_bias_relu_maxpool(x, w, b, pool=True)
    ref = conv3x3_bias_relu_maxpool_ref(x, w, b, pool=True)
    assert_close(fused, ref, rtol=1e-3, atol=1e-4)


def test_calibration_site_shape():
    """The conv_site artifact geometry used by the rust devsim calibration."""
    x, w, b = _mk(13, 1, 56, 56, 64, 64, scale=0.05)
    out = conv3x3_bias_relu_maxpool(x, w, b)
    assert out.shape == (1, 28, 28, 64)
    assert_close(out, conv3x3_bias_relu_maxpool_ref(x, w, b), rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("cout", [5, 7])  # non-LANE-divisible channel counts
def test_awkward_cout_tiles(cout):
    x, w, b = _mk(17, 1, 4, 4, 3, cout)
    assert_close(
        conv3x3_bias_relu_maxpool(x, w, b),
        conv3x3_bias_relu_maxpool_ref(x, w, b),
        rtol=1e-3, atol=1e-4,
    )
