"""Shared fixtures/helpers for the L1/L2 test suite."""

from __future__ import annotations

import jax
import numpy as np
import pytest
from hypothesis import settings

# interpret-mode Pallas is slow; keep hypothesis budgets tight but meaningful.
settings.register_profile("sol", max_examples=12, deadline=None)
settings.load_profile("sol")


def rand(key: int, shape, dtype=np.float32, scale: float = 1.0):
    rng = np.random.default_rng(key)
    return (rng.standard_normal(shape) * scale).astype(dtype)


def assert_close(a, b, rtol=1e-4, atol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


@pytest.fixture(scope="session")
def cpu():
    return jax.devices("cpu")[0]
