"""AOT pipeline: HLO-text emission, manifest integrity, fingerprint skip."""

import json
import os

import jax
import pytest

from compile import model as M
from compile.aot import sig_of, source_fingerprint, to_hlo_text

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_is_parseable_text():
    fn, specs = M.ENTRIES["avgpool_sol"]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    assert text.startswith("HloModule"), text[:50]
    assert "ENTRY" in text


def test_hlo_output_is_tuple():
    """return_tuple=True: rust unwraps with to_tuple1/to_tuple — the root
    instruction must be tuple-shaped."""
    fn, specs = M.ENTRIES["avgpool_sol"]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    # the ENTRY computation's ROOT must produce a tuple
    root_lines = [l for l in text.splitlines() if "ROOT" in l]
    assert any("tuple" in l or "(f32" in l for l in root_lines), root_lines


def test_sig_of():
    s = jax.ShapeDtypeStruct((2, 3), jax.numpy.float32)
    assert sig_of(s) == {"shape": [2, 3], "dtype": "f32"}
    s = jax.ShapeDtypeStruct((4,), jax.numpy.int32)
    assert sig_of(s) == {"shape": [4], "dtype": "i32"}


def test_fingerprint_stable():
    assert source_fingerprint() == source_fingerprint()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    @pytest.fixture(autouse=True)
    def _load(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            self.manifest = json.load(f)

    def test_manifest_covers_registry(self):
        assert set(self.manifest["entries"]) == set(M.ENTRIES)

    def test_all_hlo_files_exist_and_nonempty(self):
        for name in self.manifest["entries"]:
            p = os.path.join(ART, f"{name}.hlo.txt")
            assert os.path.exists(p), p
            assert os.path.getsize(p) > 100, p

    def test_signatures_match_registry(self):
        for name, meta in self.manifest["entries"].items():
            _, specs = M.ENTRIES[name]
            assert len(meta["inputs"]) == len(specs), name
            for sig, s in zip(meta["inputs"], specs):
                assert tuple(sig["shape"]) == tuple(s.shape), name

    def test_train_entries_return_params_plus_loss(self):
        e = self.manifest["entries"]["mlp_train_sol_b64"]
        assert len(e["outputs"]) == 7  # 6 params + loss
        assert e["outputs"][-1]["shape"] == []  # scalar loss
