"""AveragePooling DFP kernel (paper Listing 3) vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import avgpool_3x3
from compile.kernels.ref import avgpool_3x3_ref

from .conftest import assert_close, rand


@given(
    c=st.sampled_from([1, 3, 8, 16, 64]),
    h=st.integers(1, 12),
    w=st.integers(1, 12),
    seed=st.integers(0, 2**16),
)
def test_matches_ref_shape_sweep(c, h, w, seed):
    x = rand(seed, (c, h + 2, w + 2))
    assert_close(avgpool_3x3(x), avgpool_3x3_ref(x))


@pytest.mark.parametrize("kh,kw", [(1, 1), (2, 2), (3, 3), (5, 3)])
def test_kernel_sizes(kh, kw):
    x = rand(1, (8, 10 + kh - 1, 10 + kw - 1))
    assert_close(avgpool_3x3(x, kh=kh, kw=kw), avgpool_3x3_ref(x, kh=kh, kw=kw))


def test_listing3_shape():
    """The paper's exact Listing-3 geometry: 512 channels, 128x128, 3x3."""
    x = rand(3, (512, 130, 130))
    out = avgpool_3x3(x)
    assert out.shape == (512, 128, 128)
    assert_close(out, avgpool_3x3_ref(x))


def test_count_include_pad_semantics():
    """Divisor is always kh*kw, even where the window covers padding zeros."""
    x = np.zeros((1, 5, 5), np.float32)
    x[0, 2, 2] = 9.0  # center contributes 9/9 = 1.0 to every covering window
    out = np.asarray(avgpool_3x3(jnp.asarray(x)))
    assert out[0, 1, 1] == pytest.approx(1.0)


def test_constant_input_is_identity():
    x = np.full((4, 8, 8), 2.5, np.float32)
    assert_close(avgpool_3x3(jnp.asarray(x)), np.full((4, 6, 6), 2.5, np.float32))
