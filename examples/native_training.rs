//! Native offloading (paper §V-B): the SX-Aurora backend registers itself
//! into the framework's vacant HIP dispatcher slot — hooks, allocator and
//! the minimal kernel set — and then an UNMODIFIED framework training loop
//! runs with its tensors on `hip:0`.
//!
//! Trains a small classifier on synthetic data; the forward/loss run on
//! the device through the framework dispatcher, gradients are computed
//! with finite differences on the loss (the framework is deliberately
//! autograd-free: the paper keeps "learning methods" in the framework and
//! this stays faithful to dispatch-level integration).
//!
//! Run: `cargo run --release --example native_training`

use sol::framework::dispatcher::Attrs;
use sol::framework::{install_default, DeviceType, Module, Tensor};
use sol::framework::allocator::Allocator;
use sol::frontend::install_native_backend;
use sol::session::Session;

fn main() -> anyhow::Result<()> {
    // the session's backend registry resolves which SOL backend squats on
    // the framework's vacant HIP slot (paper §V-B)
    let session = Session::new();
    let squatter = session
        .registry()
        .by_framework_slot(DeviceType::Hip)
        .first()
        .map(|b| (b.name(), b.device()))
        .expect("a backend must claim the HIP slot");
    println!("registry: {} drives {:?} via the hip slot", squatter.0, squatter.1);

    // stock framework + SOL's native backend (no framework code changed)
    let mut reg = install_default();
    let backend = install_native_backend(&mut reg)?;
    println!(
        "hip:0 up — {} ops registered on the HIP slot",
        reg.ops_for_device(DeviceType::Hip).len()
    );

    // a tiny linear classifier trained with SPSA-style perturbation steps
    // (all compute dispatched to hip:0)
    let model = Module::linear(16, 4, 11);
    let n = 64usize;
    let mut xs = Vec::with_capacity(n * 16);
    let mut ys = Vec::with_capacity(n);
    for i in 0..n {
        let class = (i % 4) as i32;
        for j in 0..16 {
            let base = if j / 4 == class as usize { 1.5 } else { 0.0 };
            xs.push(base + 0.3 * ((i * 16 + j) as f32).sin());
        }
        ys.push(class);
    }
    let x_dev = backend.to_device(&Tensor::from_f32(xs, &[n, 16]))?;
    let labels = Tensor::from_i32(ys, &[n]);

    let loss_of = |reg: &sol::framework::OperatorRegistry, m: &Module| -> anyhow::Result<f32> {
        let logits = m.forward(reg, &x_dev)?;
        let logits_host = backend.to_host(&logits)?;
        let l = reg.dispatch(
            "aten::cross_entropy",
            DeviceType::Cpu,
            &[logits_host, labels.clone()],
            &Attrs::new(),
        )?;
        l.item()
    };

    println!("training on hip:0 (loss must decrease):");
    let mut last = f32::INFINITY;
    let mut first = 0.0;
    for epoch in 0..30 {
        // numerical gradient on the weight via symmetric perturbation of
        // each output row (cheap for a 16x4 head)
        let (wname, w) = &model.parameters()[0];
        let wv = w.to_f32()?;
        let mut grad = vec![0f32; wv.len()];
        let eps = 1e-2f32;
        for i in 0..wv.len() {
            let mut plus = wv.clone();
            plus[i] += eps;
            w.set_f32(plus)?;
            let lp = loss_of(&reg, &model)?;
            let mut minus = wv.clone();
            minus[i] -= eps;
            w.set_f32(minus)?;
            let lm = loss_of(&reg, &model)?;
            grad[i] = (lp - lm) / (2.0 * eps);
        }
        w.set_f32(wv)?;
        w.sub_scaled_(&Tensor::from_f32(grad, &w.shape), 0.5)?;
        let _ = wname;
        let l = loss_of(&reg, &model)?;
        if epoch == 0 {
            first = l;
        }
        if epoch % 5 == 0 || epoch == 29 {
            println!("  epoch {epoch:>2}: loss {l:.4}");
        }
        last = l;
    }
    assert!(last < first * 0.7, "no learning: {first} -> {last}");
    println!(
        "device memory in use: {} B across {} allocations-worth",
        backend.store.allocated_bytes(),
        backend.compute_op_count()
    );
    println!("native_training OK (loss {first:.3} -> {last:.3})");
    Ok(())
}
