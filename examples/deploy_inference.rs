//! Deployment mode (paper §III-C): extract the optimized model into a
//! self-contained bundle with **no framework dependency**, load it like a
//! user application would, and serve batched inference requests.
//!
//! Run: `cargo run --release --example deploy_inference`

use sol::deploy::{write_bundle, DeployedModel};
use sol::devsim::DeviceId;
use sol::metrics::Timer;
use sol::runtime::manifest::Manifest;
use sol::session::Session;
use sol::util::XorShift;
use sol::workloads::NetId;

fn cnn_params(rng: &mut XorShift) -> Vec<Vec<f32>> {
    [
        vec![3usize, 3, 3, 32], vec![32], vec![3, 3, 32, 64], vec![64],
        vec![4096, 256], vec![256], vec![256, 10], vec![10],
    ]
    .iter()
    .map(|s| rng.normal_vec(s.iter().product(), 0.08))
    .collect()
}

fn main() -> anyhow::Result<()> {
    // ---- build the bundle (the "SOL compiler deployment mode") ---------
    let manifest = Manifest::load(Manifest::default_dir())?;
    let session = Session::new();
    let model = session.compile(&NetId::Squeezenet1_1.build(1), DeviceId::Xeon6126);
    let dir = std::env::temp_dir().join("sol_deploy_demo");
    let _ = std::fs::remove_dir_all(&dir);
    write_bundle(&model, &["cnn_infer_sol_b1", "cnn_infer_sol_b32"], &manifest, &dir)?;
    let files: Vec<String> = std::fs::read_dir(&dir)?
        .flatten()
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    println!("bundle at {dir:?}: {files:?}");

    // ---- load it as a standalone library (no framework, no SOL state) --
    let dep = DeployedModel::load(&dir)?;
    let mut rng = XorShift::new(17);
    let params = cnn_params(&mut rng);

    // single-image latency
    let mut lat = Vec::new();
    for _ in 0..20 {
        let mut inputs = params.clone();
        inputs.push(rng.normal_vec(32 * 32 * 3, 1.0));
        let t = Timer::start();
        let out = dep.run_f32("cnn_infer_sol_b1", &inputs)?;
        lat.push(t.ms());
        assert_eq!(out[0].as_f32()?.len(), 10);
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());

    // batched throughput
    let mut inputs = params.clone();
    inputs.push(rng.normal_vec(32 * 32 * 32 * 3, 1.0));
    let t = Timer::start();
    let reps = 10;
    for _ in 0..reps {
        dep.run_f32("cnn_infer_sol_b32", &inputs)?;
    }
    let batch_ms = t.ms() / reps as f64;

    println!("b=1  latency: p50 {:.2} ms, p95 {:.2} ms", lat[10], lat[18]);
    println!(
        "b=32 throughput: {:.0} img/s ({batch_ms:.2} ms/batch)",
        32.0 * 1e3 / batch_ms
    );
    std::fs::remove_dir_all(&dir)?;
    println!("deploy_inference OK");
    Ok(())
}
