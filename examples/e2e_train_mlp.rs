//! END-TO-END driver: train the paper's MLP (3 layers, 8192 features,
//! ~134M parameters, §VI-B) for real, through the full stack —
//!
//!   L1 Pallas `linear_relu` kernels (fused fwd, library bwd)
//!   L2 jax train-step graph, AOT-lowered to HLO text
//!   L3 this rust driver: PJRT engine loads + executes the artifact;
//!      parameters live host-side exactly like the transparent-offloading
//!      training loop of §V-A.
//!
//! Prints a loss curve on a synthetic 10-class problem; the loss must fall
//! from ~ln(10) toward 0.  Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example e2e_train_mlp -- [steps] [batch]`
//! (defaults: 30 steps, batch 16; batch must be one of {16, 64})

use sol::devsim::DeviceId;
use sol::metrics::Timer;
use sol::runtime::pjrt::{HostTensor, PjrtEngine};
use sol::session::Session;
use sol::util::XorShift;
use sol::workloads::NetId;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(30);
    let batch: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(16);
    let entry = format!("mlp_train_sol_b{batch}");

    // the coordinator's compile view of the same workload: the session
    // pipeline plans the schedule the PJRT artifact implements
    let session = Session::new();
    let plan = session.compile(&NetId::Mlp.build(batch), DeviceId::Xeon6126);
    println!(
        "session plan: {} kernels ({} DNN library calls), {:.1} ms simulated autotune",
        plan.kernel_count(),
        plan.kernel_count() - plan.dfp_kernel_count(),
        plan.autotune_us / 1e3
    );

    let engine = PjrtEngine::new()?;
    println!("PJRT platform: {}", engine.platform());
    let sig = engine.manifest.entry(&entry)?.clone();
    let n_params: usize = sig.inputs[..6].iter().map(|s| s.elems()).sum();
    println!("model: mlp 8192-8192-8192-10, {n_params} parameters ({:.0} MB)", n_params as f64 * 4.0 / 1e6);

    let mut rng = XorShift::new(7);
    let mut params: Vec<HostTensor> = sig.inputs[..6]
        .iter()
        .map(|s| {
            let scale = if s.shape.len() == 2 { 0.01 } else { 0.0 };
            HostTensor::F32(rng.normal_vec(s.elems(), scale))
        })
        .collect();

    let t_compile = Timer::start();
    engine.load(&entry)?;
    println!("compiled {entry} in {:.1} s", t_compile.ms() / 1e3);

    let mut first = 0.0f32;
    let mut last = 0.0f32;
    let t_all = Timer::start();
    for step in 0..steps {
        // synthetic 10-class batch: class-dependent bump on 64 features
        let labels: Vec<i32> = (0..batch).map(|_| (rng.below(10)) as i32).collect();
        let mut x = rng.normal_vec(batch * 8192, 0.1);
        for (i, &l) in labels.iter().enumerate() {
            for j in 0..64 {
                x[i * 8192 + (l as usize) * 64 + j] += 1.0;
            }
        }
        let mut inputs = params.clone();
        inputs.push(HostTensor::F32(x));
        inputs.push(HostTensor::I32(labels));
        let t = Timer::start();
        let mut out = engine.run(&entry, &inputs)?;
        let loss = out.pop().unwrap().scalar_f32()?;
        params = out; // updated parameters flow back (host-side, §V-A)
        if step == 0 {
            first = loss;
        }
        last = loss;
        println!("step {step:>3}  loss {loss:.4}  ({:>6.0} ms/step)", t.ms());
    }
    let total_s = t_all.ms() / 1e3;
    let gflops_per_step = 6.0 * (batch as f64) * (2.0 * 8192.0 * 8192.0 + 8192.0 * 10.0) / 1e9;
    println!(
        "\n{} steps in {:.1} s — {:.2} GFLOP/step, {:.1} GFLOP/s sustained",
        steps,
        total_s,
        gflops_per_step,
        gflops_per_step * steps as f64 / total_s
    );
    assert!(first > 1.8, "initial loss should be near ln(10)=2.30, got {first}");
    assert!(last < first * 0.8, "loss must decrease: {first} -> {last}");
    println!("e2e_train_mlp OK (loss {first:.3} -> {last:.3})");
    Ok(())
}
