//! Transparent offloading (paper §V-A): `sol.device.set(DEVICE)` and the
//! model runs on the accelerator even though the framework only ever sees
//! host tensors — Keras-style.
//!
//! Demonstrates the parameter-context cache: the first run uploads the
//! weights (packed, §IV-C), steady-state runs move only input/output, and
//! a framework-side weight update invalidates the context.
//!
//! Run: `cargo run --release --example transparent_offload`

use sol::devsim::DeviceId;
use sol::framework::optim::Sgd;
use sol::framework::{Module, Tensor};
use sol::frontend::{SolModel, TransparentOffload};
use sol::session::Session;

fn main() -> anyhow::Result<()> {
    let py_model = Module::Sequential(vec![
        Module::conv2d(3, 24, 3, 1, 1, 7),
        Module::ReLU,
        Module::MaxPool2d { k: 2, stride: 2, pad: 0 },
        Module::conv2d(24, 48, 3, 1, 1, 8),
        Module::ReLU,
        Module::GlobalAvgPool,
        Module::Flatten,
        Module::linear(48, 10, 9),
    ]);
    let session = Session::new();
    let sol_model = SolModel::optimize_in(
        &session,
        &py_model,
        &[1, 3, 32, 32],
        "to_demo",
        DeviceId::AuroraVE10B,
    )?;

    // sol.device.set(DEVICE, IDX)
    let mut to = TransparentOffload::set_device(DeviceId::AuroraVE10B);
    let x = Tensor::randn(&[1, 3, 32, 32], 5, 0.5);

    println!("-- inference: parameter context cached after first run --");
    for run in 0..4 {
        let before = to.h2d_bytes;
        let out = to.forward(&sol_model, &x)?;
        println!(
            "run {run}: h2d {:>9} B (ctx live: {}, wire ops so far: {}, out[0]={:.4})",
            to.h2d_bytes - before,
            to.context_live(),
            to.wire_ops,
            out.to_f32()?[0]
        );
    }
    println!("param uploads: {} (expect 1)", to.param_uploads);
    assert_eq!(to.param_uploads, 1);

    println!("\n-- framework-side weight update invalidates the context --");
    let params = py_model.parameters();
    Sgd::new(0.1).step(&params, &params)?; // p -= 0.1*p, bumps versions
    to.forward(&sol_model, &x)?;
    println!("param uploads after update: {} (expect 2)", to.param_uploads);
    assert_eq!(to.param_uploads, 2);

    println!("\n-- training: §V-A's per-step weight/gradient tax --");
    let d2h_before = to.d2h_bytes;
    for _ in 0..3 {
        let params = py_model.parameters();
        to.train_step(&sol_model, &x, || Sgd::new(0.01).step(&params, &params))?;
    }
    println!(
        "3 training steps moved {} B of gradients D2H and re-uploaded params {} times",
        to.d2h_bytes - d2h_before,
        to.param_uploads - 2
    );
    // step 1 reuses the post-update context; steps 2 and 3 re-upload
    // (and the optimizer left one more invalidation pending)
    assert_eq!(to.param_uploads, 4);
    println!("transparent_offload OK");
    Ok(())
}
