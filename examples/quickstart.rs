//! Quickstart — the paper's Listing 1, in Torchlet + SOL:
//!
//! ```python
//! py_model  = initPyTorchModel()
//! opt_model = sol.optimize(py_model, copy_parameters=True)
//! output    = opt_model(input)
//! ```
//!
//! Builds a small CNN in the (unmodified) framework, optimizes it with the
//! SOL middleware for every evaluation device, runs both models and checks
//! they agree numerically.
//!
//! Run: `cargo run --release --example quickstart`

use sol::devsim::DeviceId;
use sol::framework::{install_default, Module, Tensor};
use sol::frontend::SolModel;
use sol::session::Session;

fn main() -> anyhow::Result<()> {
    // ---- 1. a normal framework model (PyTorch stand-in) ----------------
    let py_model = Module::Sequential(vec![
        Module::conv2d(3, 16, 3, 1, 1, 1),
        Module::batch_norm(16),
        Module::ReLU,
        Module::MaxPool2d { k: 2, stride: 2, pad: 0 },
        Module::conv2d(16, 32, 3, 1, 1, 2),
        Module::ReLU,
        Module::MaxPool2d { k: 2, stride: 2, pad: 0 },
        Module::Flatten,
        Module::linear(32 * 8 * 8, 10, 3),
        Module::Softmax,
    ]);
    let reg = install_default();
    let input = Tensor::randn(&[4, 3, 32, 32], 42, 0.5);

    // ---- 2. sol.optimize(py_model) through a compilation session --------
    let session = Session::new();
    let sol_model = SolModel::optimize_in(
        &session,
        &py_model,
        &[4, 3, 32, 32],
        "quickstart_cnn",
        DeviceId::Xeon6126,
    )?;
    println!(
        "optimized: {} framework layers -> {} SOL kernels ({} elided, {} DFP regions)",
        sol_model.graph.layer_count(),
        sol_model.optimized.kernel_count(),
        sol_model.optimized.elided_layers,
        sol_model.optimized.dfp_kernel_count(),
    );

    // ---- 3. run both; numerics must agree -------------------------------
    let reference = py_model.forward(&reg, &input)?;
    let optimized = sol_model.forward(&input)?;
    let (a, b) = (reference.to_f32()?, optimized.to_f32()?);
    let max_err = a
        .iter()
        .zip(&b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    println!("max |py - sol| = {max_err:.2e}");
    assert!(max_err < 1e-4, "numerics diverged");

    // ---- 4. the same model compiles for every device --------------------
    for dev in DeviceId::ALL {
        let m = SolModel::optimize_in(&session, &py_model, &[4, 3, 32, 32], "quickstart_cnn", dev)?;
        println!(
            "  {:?}: {} kernels, {:.1} MB traffic",
            dev,
            m.optimized.kernel_count(),
            m.optimized.total_hbm_bytes() as f64 / 1e6
        );
    }
    // the CPU artifact was already in the session's compile cache (step 2)
    println!(
        "compile cache: {} hits / {} misses over {} artifacts",
        session.cache().hits(),
        session.cache().misses(),
        session.cache().len()
    );
    assert!(session.cache().hits() >= 1, "Xeon recompile must hit the cache");
    println!("quickstart OK");
    Ok(())
}
