fn main() -> anyhow::Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file("/tmp/multi.hlo.txt")?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
    let a = xla::Literal::vec1(&[1f32,2.,3.,4.]).reshape(&[2,2])?;
    let b = xla::Literal::vec1(&[5f32,6.,7.,8.]).reshape(&[2,2])?;
    let r = exe.execute::<xla::Literal>(&[a, b])?;
    println!("outer len = {}", r.len());
    for (i, row) in r.iter().enumerate() {
        println!("  output {i}: inner len {} -> {:?}", row.len(), row[0].to_literal_sync()?.to_vec::<f32>()?);
    }
    // feed an output buffer back in
    let r2 = exe.execute_b(&[&r[0][0], &r[1][0]])?;
    println!("feedback ok: {:?}", r2[0][0].to_literal_sync()?.to_vec::<f32>()?);
    Ok(())
}
