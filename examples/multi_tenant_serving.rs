//! Multi-tenant serving: many tenants, many nets, one bounded-cache
//! `ServingSession` over all four devices.
//!
//! Demonstrates the serving layer's contracts end to end:
//!
//! * tenants requesting the same network/device share one compiled
//!   artifact (one cache miss process-wide, hits for everyone else);
//! * the shared compile cache is bounded — once the working set exceeds
//!   its capacity, unpinned artifacts are evicted (and never ones still
//!   pinned by a tenant or a live executor);
//! * every tenant's `compiles / cache_hits / runs / evicted` counters are
//!   tracked individually and surfaced both by `serving_report()` and the
//!   process-wide `metrics` registry.
//!
//! Run: `cargo run --release --example multi_tenant_serving`

use sol::devsim::DeviceId;
use sol::exec::solrun::OffloadMode;
use sol::metrics;
use sol::session::{EvictionPolicy, Phase, ServingConfig, ServingSession};
use sol::util::XorShift;
use sol::workloads::NetId;

fn main() {
    let serving = ServingSession::new(ServingConfig {
        cache_capacity: 12,
        eviction_policy: EvictionPolicy::Lru,
        max_inflight_compiles: 2,
        max_resident_per_tenant: 4,
    });

    // the small half of the model zoo: enough distinct content addresses
    // (8 nets x 4 devices) to put real pressure on a 12-entry cache
    let nets = [
        NetId::Resnet18,
        NetId::Squeezenet1_0,
        NetId::Squeezenet1_1,
        NetId::ShufflenetV2X0_5,
        NetId::ShufflenetV2X1_0,
        NetId::Mnasnet0_5,
        NetId::Mnasnet1_0,
        NetId::Mlp,
    ];

    println!("4 tenants x 64 requests over {} nets x {} devices:", nets.len(), DeviceId::ALL.len());
    std::thread::scope(|scope| {
        for i in 0..4usize {
            let tenant = serving.tenant(&format!("tenant-{i}"));
            let nets = &nets;
            scope.spawn(move || {
                let mut rng = XorShift::new(1234 + i as u64);
                for _ in 0..64 {
                    let net = *rng.pick(nets);
                    let dev = DeviceId::ALL[rng.below(DeviceId::ALL.len())];
                    let g = net.build(1);
                    match tenant.compile(&g, dev) {
                        Ok(model) => {
                            let report = tenant.run(&model, OffloadMode::Native, Phase::infer());
                            assert!(report.total_us > 0.0);
                        }
                        // at the in-flight limit the request is rejected,
                        // not queued — a real frontend would back off/retry
                        Err(rejected) => eprintln!("{rejected}"),
                    }
                }
            });
        }
    });

    print!("{}", serving.serving_report());

    println!("\nprocess-wide serving counters (metrics registry):");
    for (name, value) in metrics::counters_snapshot() {
        if name.starts_with("serve.") || name.starts_with("compile_cache.") {
            println!("  {name:<28} {value}");
        }
    }
}
